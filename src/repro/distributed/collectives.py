"""Distributed-optimization collectives.

* :func:`compressed_psum` — int8 stochastic-rounding gradient compression
  for cross-data-axis gradient reduction: per-block scales, quantize →
  psum in int32 → dequantize.  Cuts gradient all-reduce bytes 2× vs bf16
  (4× vs fp32) at the cost of quantization noise that stochastic rounding
  keeps unbiased.  Used via :func:`compressed_grad_sync` under shard_map
  for the FSDP data axes (the collective-bound term of the kimi-1T train
  cell, EXPERIMENTS §Perf cell 2).
* :func:`split_kv_attention` — sequence-parallel decode attention: each
  shard computes flash partials over its KV slice; (m, l, acc) combine
  exactly with pmax/psum.  The pjit path achieves the same via sharding
  constraints (models/layers.flash_partial reductions partition over the
  kv_seq axis); this explicit shard_map form is used where manual control
  is needed (tests document the equivalence).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

NEG_INF = -1e30


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions: new API (``check_vma``) when
    present, ``jax.experimental.shard_map`` (``check_rep``) otherwise —
    replication checking off in both (bodies use explicit collectives)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


# ---------------------------------------------------------------------------
# int8 stochastic-rounding compressed gradient reduction
# ---------------------------------------------------------------------------

def _quantize_sr(x, rng, block: int = 256):
    """Stochastic-rounding int8 quantization with per-block scales.

    x [N] fp → (q int8 [N], scales fp32 [ceil(N/block)])."""
    n = x.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    y = xp / scale
    lo = jnp.floor(y)
    frac = y - lo
    u = jax.random.uniform(rng, y.shape)
    q = lo + (u < frac)                          # unbiased rounding
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def _dequantize(q, scale, n, block: int = 256):
    x = q.astype(jnp.float32).reshape(-1, block) * scale[:, None]
    return x.reshape(-1)[:n]


def compressed_psum(x, axis_name, rng, block: int = 256):
    """psum of ``x`` over ``axis_name`` with int8 payload.

    Each participant quantizes with stochastic rounding; int32 psum of the
    int8 payloads (exact) + fp32 psum of the tiny per-block scales — the
    result is the sum of the participants' dequantized values, unbiased in
    expectation.  Payload: 1 byte/elem + 4/block ≈ 2× cheaper than bf16."""
    n = x.size
    flat = x.reshape(-1)
    q, scale = _quantize_sr(flat, rng, block)
    # sum of per-shard (q_i * scale_i): transmit q*1B; scales are negligible.
    # To keep the reduction exact we psum q_i scaled into a shared grid:
    # use the max scale across shards so int32 accumulation is lossless.
    smax = jax.lax.pmax(scale, axis_name)
    ratio = scale / smax                          # ≤ 1
    qs = jnp.round(q.astype(jnp.float32).reshape(-1, block)
                   * ratio[:, None]).astype(jnp.int32)
    total = jax.lax.psum(qs, axis_name)
    out = (total.astype(jnp.float32) * smax[:, None]).reshape(-1)[:q.size]
    return out[:n].reshape(x.shape).astype(x.dtype)


def compressed_grad_sync(grads, mesh, data_axes, rng, block: int = 256):
    """Tree-map compressed_psum over a gradient pytree under shard_map.

    Grads are assumed replicated over ``data_axes`` *per microbatch partial*
    (pre-reduction); the result equals the cross-data psum up to int8
    stochastic-rounding noise."""
    axis = data_axes if isinstance(data_axes, str) else data_axes[0]

    leaves, treedef = jax.tree.flatten(grads)
    rngs = jax.random.split(rng, len(leaves))

    def one(g, r):
        fn = shard_map_compat(
            functools.partial(compressed_psum, axis_name=axis, rng=r,
                              block=block),
            mesh=mesh, in_specs=P(), out_specs=P())
        return fn(g)

    return treedef.unflatten([one(g, r) for g, r in zip(leaves, rngs)])


# ---------------------------------------------------------------------------
# explicit split-KV decode attention (sequence parallel)
# ---------------------------------------------------------------------------

def _split_kv_body(q, k, v, klen, *, axis_name, scale):
    """Per-shard flash partial over the local KV slice + exact combine."""
    S_loc = k.shape[1]
    shard = jax.lax.axis_index(axis_name)
    base = shard * S_loc
    pos = base + jnp.arange(S_loc)[None, :]                    # [1, S_loc]
    mask = (pos < klen[:, None])[:, None, None, :]             # [B,1,1,S]
    from repro.kernels.ops import combine_flash_partials
    from repro.models.layers import sdpa_partial
    part = sdpa_partial(q, k, v, mask, scale=scale)
    return combine_flash_partials([part], out_dtype=q.dtype,
                                  axis_name=axis_name)


def split_kv_attention(q, k_cache, v_cache, kv_lens, mesh, *,
                       seq_axis: str = "model", scale: float | None = None):
    """q [B,c,H,D] (replicated over seq_axis), KV cache [B,S,KVH,D] sharded
    on S over ``seq_axis`` → exact attention output [B,c,H,D]."""
    scale = scale if scale is not None else 1.0 / np.sqrt(q.shape[-1])
    body = functools.partial(_split_kv_body, axis_name=seq_axis, scale=scale)
    fn = shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(), P(None, seq_axis, None, None),
                  P(None, seq_axis, None, None), P()),
        out_specs=P())
    return fn(q, k_cache, v_cache, kv_lens)


# ---------------------------------------------------------------------------
# split-KV paged decode attention (sharded page pool)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class KVShardSpec:
    """Static description of a sharded page pool.

    ``mesh`` must carry ``axis`` with size ``n_shards``; the pool's page
    dim is block-sharded over it (shard *s* physically owns global pages
    ``[s·P/S, (s+1)·P/S)``), while the allocator stripes each request's
    *table slots* round-robin from a per-request offset: global table slot
    ``j`` of a request with stripe offset ``o`` lives on shard
    ``(o + j) % S`` (see ``PagedKVAllocator``).  That strict striping is
    what lets every shard derive its local table, local page indices and
    local context length on device from the replicated global table — no
    per-shard host-side tables cross PCIe.
    """
    mesh: object
    n_shards: int
    axis: str = "kv"


def _local_slots(tables, ctx_lens, stripe_offs, shard, *, n_shards,
                 pages_local, page_size):
    """Shard-local view of the replicated global block tables.

    For shard ``s``, local slot ``i`` holds the request's global table slot
    ``j = i·S + (s - o) % S`` — ascending in ``j``, so every local slot
    before the request's (single, final) partial page is a FULL page and
    the kernel's contiguous ``pos < ctx`` masking stays valid after the
    shard-local reorder.  Returns (local_tables [B, Wl] int32 local page
    ids clipped in-bounds, local_ctx [B] int32 valid tokens on this shard).
    """
    B, W = tables.shape
    S = n_shards
    Wl = -(-W // S)
    d = (shard - stripe_offs) % S                         # [B]
    j = jnp.arange(Wl)[None, :] * S + d[:, None]          # [B, Wl] global slot
    gl = jnp.take_along_axis(tables, jnp.minimum(j, W - 1), axis=1)
    local = jnp.clip(gl - shard * pages_local, 0, pages_local - 1)
    # tokens contributed by global slot j: ps for full pages, the tail for
    # the request's last page, 0 past the context (incl. clamped j ≥ W —
    # ctx ≤ W·ps always, so those slots mask themselves)
    tok = jnp.clip(ctx_lens[:, None] - j * page_size, 0, page_size)
    local_ctx = jnp.sum(tok, axis=1)
    return local.astype(jnp.int32), local_ctx.astype(jnp.int32)


def split_kv_paged_partial(q, k_pages, v_pages, block_tables, ctx_lens,
                           stripe_offs, ks: KVShardSpec, *,
                           impl: str = "kernel", interpret: bool = True,
                           scale: float | None = None):
    """Split-KV chunked paged attention across ``ks.axis``.

    q [B,c,H,D] replicated; k/v_pages [P,ps,KVH,D] page-dim-sharded;
    block_tables [B,W] GLOBAL page ids (replicated, strict striping per
    :class:`KVShardSpec`); ctx_lens/stripe_offs [B].  Each shard runs
    ``paged_chunk_attention_kernel`` (or the jnp oracle) over its local
    pages only, then the flash partials merge exactly across shards
    (``merge_flash_partials`` pmax/psum).  Returns the *merged partial*
    ``(acc [B,c,H,D] fp32, m [B,c,H], l [B,c,H])`` replicated — the same
    contract as the unsharded kernel, so the caller combines it with the
    in-window partial unchanged.
    """
    P_g, ps = k_pages.shape[0], k_pages.shape[1]
    P_loc = P_g // ks.n_shards

    def body(q_, kp, vp, tables, ctx, offs):
        shard = jax.lax.axis_index(ks.axis)
        lt, lctx = _local_slots(tables, ctx, offs, shard,
                                n_shards=ks.n_shards, pages_local=P_loc,
                                page_size=ps)
        if impl == "ref":
            from repro.kernels import ref
            part = ref.paged_chunk_ref(q_, kp, vp, lt, lctx, scale=scale)
        else:
            from repro.kernels.chunked_paged_attn import \
                paged_chunk_attention_kernel
            part = paged_chunk_attention_kernel(
                q_, kp, vp, lt, lctx, scale=scale, interpret=interpret)
        from repro.kernels.ops import merge_flash_partials
        return merge_flash_partials([part], axis_name=ks.axis)

    fn = shard_map_compat(
        body, mesh=ks.mesh,
        in_specs=(P(), P(ks.axis), P(ks.axis), P(), P(), P()),
        out_specs=(P(), P(), P()))
    return fn(q, k_pages, v_pages, block_tables.astype(jnp.int32),
              ctx_lens.astype(jnp.int32), stripe_offs.astype(jnp.int32))


def scatter_pages_sharded(pages, new, dest, ks: KVShardSpec):
    """Sharded counterpart of the models' token-granular page scatter.

    pages [L,P,ps,KVH,hd] page-dim-sharded over ``ks.axis``; new
    [L,B,T,KVH,hd] and flat global dest [B,T] replicated.  Each shard
    rewrites ``dest`` into its local flat index (out-of-shard and sentinel
    entries → local OOB, dropped), so the scatter stays shard-local —
    no cross-shard traffic, and XLA can alias the pool buffers per shard
    (the donation contract the fused decode step asserts on its HLO).
    """
    L, P_g, ps, KVH, hd = pages.shape
    P_loc = P_g // ks.n_shards

    def body(pg, new_, dest_):
        shard = jax.lax.axis_index(ks.axis)
        base = shard * P_loc * ps
        d = dest_ - base
        d = jnp.where((d >= 0) & (d < P_loc * ps), d, P_loc * ps)
        flat = pg.reshape(L, P_loc * ps, KVH, hd)
        flat = flat.at[:, d.reshape(-1)].set(
            new_.astype(pg.dtype).reshape(L, -1, KVH, hd), mode="drop")
        return flat.reshape(L, P_loc, ps, KVH, hd)

    fn = shard_map_compat(
        body, mesh=ks.mesh,
        in_specs=(P(None, ks.axis), P(), P()),
        out_specs=P(None, ks.axis))
    return fn(pages, new, dest)
