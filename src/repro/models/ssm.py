"""State-space sequence mixers: Mamba (selective SSM, for Jamba) and RWKV6.

Both expose the same protocol:

* ``*_seq(params, cfg, x, state)`` — process ``x [B,T,d]`` given an incoming
  recurrent state, return ``(y [B,T,d], new_state)``.  Used for training,
  prefill, and diffusion-window recompute (T = chunk size).
* fresh states from ``*_init_state(cfg, batch)``.

Training uses a chunked ``lax.scan`` (inner chunk rematerialized) so backward
memory is O(T/chunk) states instead of O(T).  All recurrences accumulate in
fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ArchConfig, dense_init_a, zeros_a


def _chunked_scan(step, carry, xs, T: int, chunk: int, remat: bool):
    """scan ``step`` over axis-0 of xs (length T) in chunks of ``chunk``."""
    if T <= chunk or T % chunk != 0:
        return jax.lax.scan(step, carry, xs)

    def inner(c, xc):
        return jax.lax.scan(step, c, xc)

    if remat:
        inner = jax.checkpoint(inner)
    nc = T // chunk
    xs_c = jax.tree.map(lambda a: a.reshape((nc, chunk) + a.shape[1:]), xs)
    carry, ys_c = jax.lax.scan(inner, carry, xs_c)
    ys = jax.tree.map(lambda a: a.reshape((T,) + a.shape[2:]), ys_c)
    return carry, ys


# ===========================================================================
# Mamba (selective SSM, mamba-1 as used by Jamba)
# ===========================================================================

def mamba_dims(cfg: ArchConfig):
    d_inner = cfg.mamba_expand * cfg.d_model
    dt_rank = max(1, int(np.ceil(cfg.d_model / 16)))
    return d_inner, dt_rank


def init_mamba(kg, cfg: ArchConfig, abstract=False):
    d = cfg.d_model
    di, dtr = mamba_dims(cfg)
    ds, dc = cfg.d_state, cfg.d_conv
    pd = cfg.pdt

    def alog(key, shape, dtype, abstract=False):
        if abstract:
            return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))
        a = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), shape)
        return jnp.log(a).astype(dtype)

    return {
        "in_proj": dense_init_a(kg(), (d, 2 * di), pd, abstract=abstract),
        "conv_w": dense_init_a(kg(), (dc, di), pd, fan_in=dc, abstract=abstract),
        "conv_b": zeros_a(kg(), (di,), pd, abstract=abstract),
        "x_proj": dense_init_a(kg(), (di, dtr + 2 * ds), pd, abstract=abstract),
        "dt_proj": dense_init_a(kg(), (dtr, di), pd, abstract=abstract),
        "dt_bias": zeros_a(kg(), (di,), pd, abstract=abstract),
        "a_log": alog(kg(), (di, ds), pd, abstract=abstract),
        "d_skip": zeros_a(kg(), (di,), pd, abstract=abstract),
        "out_proj": dense_init_a(kg(), (di, d), pd, fan_in=di, abstract=abstract),
    }


def axes_mamba(cfg: ArchConfig):
    return {
        "in_proj": ("embed_p", "mlp_p"),
        "conv_w": (None, "mlp_p"),
        "conv_b": ("mlp_p",),
        "x_proj": ("mlp_p", None),
        "dt_proj": (None, "mlp_p"),
        "dt_bias": ("mlp_p",),
        "a_log": ("mlp_p", "state"),
        "d_skip": ("mlp_p",),
        "out_proj": ("mlp_p", "embed_p"),
    }


def mamba_init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    di, _ = mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, cfg.d_state), jnp.float32),
    }


def mamba_seq(params, cfg: ArchConfig, x, state, *, chunk: int = 256):
    """x [B,T,d] → (y [B,T,d], new state)."""
    B, T, d = x.shape
    di, dtr = mamba_dims(cfg)
    ds = cfg.d_state
    cd = cfg.cdt

    xz = x @ params["in_proj"].astype(cd)
    xi, z = jnp.split(xz, 2, axis=-1)                       # [B,T,di]

    # causal depthwise conv with carried state
    conv_in = jnp.concatenate([state["conv"].astype(cd), xi], axis=1)
    kern = params["conv_w"].astype(cd)                      # [dc, di]
    dc = cfg.d_conv
    xc = sum(conv_in[:, i:i + T, :] * kern[i] for i in range(dc))
    xc = jax.nn.silu(xc + params["conv_b"].astype(cd))
    new_conv = conv_in[:, T:T + dc - 1, :] if T >= dc - 1 else \
        jnp.concatenate([state["conv"].astype(cd), xi], 1)[:, -(dc - 1):, :]

    # data-dependent dt, B, C — small projections precomputed for the whole
    # sequence; the O(T·d_inner·d_state) discretized tensors (dA, dB·x) are
    # formed PER STEP inside the scan so peak memory stays O(d_inner·d_state)
    proj = xc @ params["x_proj"].astype(cd)
    dt, Bc, Cc = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_proj"].astype(cd)
                         + params["dt_bias"].astype(cd)).astype(jnp.float32)
    A = -jnp.exp(params["a_log"].astype(jnp.float32))       # [di, ds]

    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp                           # [B,di],[B,ds]...
        da_t = jnp.exp(dt_t[..., None] * A)                 # [B,di,ds]
        dbx_t = (dt_t * x_t)[..., None] * b_t[:, None, :]
        h = da_t * h + dbx_t                                # [B,di,ds]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    xs = (jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bc.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Cc.astype(jnp.float32), 1, 0),
          jnp.moveaxis(xc.astype(jnp.float32), 1, 0))
    h, ys = _chunked_scan(step, state["ssm"], xs, T, chunk, cfg.remat)
    y = jnp.moveaxis(ys, 0, 1)                              # [B,T,di]
    y = y + xc.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)
    y = (y.astype(cd) * jax.nn.silu(z)) @ params["out_proj"].astype(cd)
    return y, {"conv": new_conv.astype(x.dtype), "ssm": h}


# ===========================================================================
# RWKV6 ("Finch") — data-dependent decay linear attention
# ===========================================================================

def init_rwkv_timemix(kg, cfg: ArchConfig, abstract=False):
    d = cfg.d_model
    hn, hd = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    r = cfg.rwkv_lora_rank
    pd = cfg.pdt
    return {
        "mu": zeros_a(kg(), (5, d), pd, abstract=abstract),   # r,k,v,g,w shifts
        "wr": dense_init_a(kg(), (d, d), pd, abstract=abstract),
        "wk": dense_init_a(kg(), (d, d), pd, abstract=abstract),
        "wv": dense_init_a(kg(), (d, d), pd, abstract=abstract),
        "wg": dense_init_a(kg(), (d, d), pd, abstract=abstract),
        "w0": zeros_a(kg(), (d,), pd, abstract=abstract),
        "w_lora_a": dense_init_a(kg(), (d, r), pd, abstract=abstract),
        "w_lora_b": dense_init_a(kg(), (r, d), pd, fan_in=r, abstract=abstract),
        "bonus_u": zeros_a(kg(), (hn, hd), pd, abstract=abstract),
        "ln_scale": zeros_a(kg(), (d,), pd, abstract=abstract),
        "wo": dense_init_a(kg(), (d, d), pd, abstract=abstract),
    }


def axes_rwkv_timemix(cfg: ArchConfig):
    return {
        "mu": (None, "embed"), "wr": ("embed_p", "heads_p"),
        "wk": ("embed_p", "heads_p"), "wv": ("embed_p", "heads_p"),
        "wg": ("embed_p", "heads_p"), "w0": ("heads_p",),
        "w_lora_a": ("embed_p", None), "w_lora_b": (None, "heads_p"),
        "bonus_u": ("heads", "head_dim"), "ln_scale": ("heads_p",),
        "wo": ("heads_p", "embed_p"),
    }


def init_rwkv_chanmix(kg, cfg: ArchConfig, abstract=False):
    d, f = cfg.d_model, cfg.d_ff
    pd = cfg.pdt
    return {
        "mu": zeros_a(kg(), (2, d), pd, abstract=abstract),   # k, r shifts
        "wk": dense_init_a(kg(), (d, f), pd, abstract=abstract),
        "wv": dense_init_a(kg(), (f, d), pd, fan_in=f, abstract=abstract),
        "wr": dense_init_a(kg(), (d, d), pd, abstract=abstract),
    }


def axes_rwkv_chanmix(cfg: ArchConfig):
    return {"mu": (None, "embed"), "wk": ("embed_p", "mlp_p"),
            "wv": ("mlp_p", "embed_p"), "wr": ("embed_p", "embed_p")}


def rwkv_init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    hn, hd = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    return {
        "tm_prev": jnp.zeros((batch, cfg.d_model), dtype),
        "cm_prev": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, hn, hd, hd), jnp.float32),
    }


def _token_shift(x, prev):
    """[B,T,d], prev [B,d] → x shifted right by one with ``prev`` at t=0."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _group_norm(x, scale, eps=1e-5):
    """Per-head LayerNorm: x [B,T,Hn,hd]."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.var(x32, -1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    B, T, hn, hd = x.shape
    s = (1.0 + scale.astype(jnp.float32)).reshape(hn, hd)
    return (y * s).reshape(B, T, hn * hd)


def rwkv_timemix(params, cfg: ArchConfig, x, state, *, chunk: int = 256):
    """RWKV6 WKV time-mix. x [B,T,d] → (y, new_state)."""
    B, T, d = x.shape
    hn, hd = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    cd = cfg.cdt
    prev = _token_shift(x, state["tm_prev"].astype(x.dtype))
    mu = params["mu"].astype(cd)
    xr, xk, xv, xg, xw = (x + (prev - x) * mu[i] for i in range(5))
    r = (xr @ params["wr"].astype(cd)).reshape(B, T, hn, hd)
    k = (xk @ params["wk"].astype(cd)).reshape(B, T, hn, hd)
    v = (xv @ params["wv"].astype(cd)).reshape(B, T, hn, hd)
    g = jax.nn.silu(xg @ params["wg"].astype(cd))
    # data-dependent decay (the RWKV6 "Finch" contribution)
    w = params["w0"].astype(jnp.float32) + \
        (jnp.tanh(xw @ params["w_lora_a"].astype(cd))
         @ params["w_lora_b"].astype(cd)).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w)).reshape(B, T, hn, hd)          # decay ∈ (0,1)
    u = params["bonus_u"].astype(jnp.float32)

    r32, k32, v32 = (a.astype(jnp.float32) for a in (r, k, v))

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                            # [B,hn,hd]
        kv = k_t[..., :, None] * v_t[..., None, :]          # [B,hn,hd,hd]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[..., :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r32, k32, v32, w))
    S, ys = _chunked_scan(step, state["wkv"], xs, T, chunk, cfg.remat)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, hn, hd)
    y = _group_norm(y, params["ln_scale"]) * g.astype(jnp.float32)
    y = y.astype(cd) @ params["wo"].astype(cd)
    return y, {"tm_prev": x[:, -1, :], "wkv": S}


def rwkv_chanmix(params, cfg: ArchConfig, x, state):
    cd = cfg.cdt
    prev = _token_shift(x, state["cm_prev"].astype(x.dtype))
    mu = params["mu"].astype(cd)
    xk = x + (prev - x) * mu[0]
    xr = x + (prev - x) * mu[1]
    k = jnp.square(jax.nn.relu(xk @ params["wk"].astype(cd)))
    kv = k @ params["wv"].astype(cd)
    y = jax.nn.sigmoid(xr @ params["wr"].astype(cd)) * kv
    return y, {"cm_prev": x[:, -1, :]}
