"""Decoder-only LM covering the dense / moe / hybrid / ssm / vlm families.

One parameter layout, four execution entry points:

* ``apply``         — full-sequence forward (training; causal or block-causal).
* ``prefill``       — full-sequence forward that also writes the KV cache /
                      recurrent states (serving admission).
* ``chunk_forward`` — diffusion-window forward: a ``c``-token window per
                      request attends to the frozen prefix cache plus itself
                      (block-causal), returning window logits and window KV.
                      This is the per-iteration unit of Optimus's streaming
                      chunked decoding.  AR decoding is the ``c=1`` special
                      case with causal semantics.
* ``freeze``        — slide the window: write the leading committed window KV
                      entries into the cache and advance ``len``.
* ``advance_states``— advance recurrent states over committed tokens (rwkv AR
                      step; hybrid block-commit, which also rewrites the
                      block's attention KV).

Layers are stacked along a leading axis and executed with ``lax.scan`` so HLO
size is depth-independent (512-device compiles stay fast).  Hybrid (Jamba)
models scan over *periods* of ``attn_period`` heterogeneous layers.

Diffusion-window semantics per family (DESIGN.md §6):
  dense/moe/vlm — window slides token-by-token past committed prefix (paper's
      streaming chunked decoding, prefix KV frozen via ``freeze``).
  hybrid — window is pinned at the current block start (recurrent layers
      recompute the ≤block_size window from the block-start state each step);
      ``advance_states`` commits a finished block.
  ssm (rwkv6) — diffusion decoding inapplicable; native AR decode via
      ``advance_states`` with T=1.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.kernels import ref as kernel_ref
from repro.kernels.chunked_paged_attn import paged_chunk_attention_kernel
from repro.models import ssm
from repro.models.common import (ArchConfig, KeyGen, dense_init_a,
                                 embed_init_a)
from repro.models.layers import (attn_output, axes_attention, axes_mlp,
                                 axes_norm, block_causal_mask, causal_mask,
                                 combine_partials, flash_partial,
                                 flash_partial_aligned, init_attention,
                                 init_mlp, init_norm, mlp_block, qkv_project,
                                 rms_norm, sdpa_partial)
from repro.models.moe import axes_moe, init_moe, moe_block


def _stack_init(init_fn, kg, cfg, n, abstract):
    """Initialize ``n`` stacked copies of a param subtree (leading dim n)."""
    if abstract:
        one = init_fn(kg, cfg, abstract=True)
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + tuple(s.shape), s.dtype), one)
    subs = [init_fn(kg, cfg, abstract=False) for _ in range(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *subs)


def _stack_axes(axes_fn, cfg):
    return jax.tree.map(lambda t: ("layers",) + t, axes_fn(cfg),
                        is_leaf=lambda x: isinstance(x, tuple))


def _scatter_kv(cache_kv, new_kv, idx):
    """cache [L,B,S,KVH,hd] ← new [L,B,T,KVH,hd] at idx [B,T] (OOB drops).

    Implemented as a one-hot contraction + select rather than a scatter:
    scattering along the sequence dim (sharded over the model axis for
    split-KV decode) triggers XLA SPMD's involuntary full rematerialization
    — replicating the multi-GB cache per step — whereas the one-hot einsum
    partitions cleanly (T ≤ chunk_size ≤ 32, so the one-hot is tiny and the
    extra FLOPs are negligible).
    """
    S = cache_kv.shape[2]
    oh = (idx[:, :, None] == jnp.arange(S)[None, None, :])     # [B,T,S]
    upd = jnp.einsum("bts,lbtkd->lbskd", oh.astype(cache_kv.dtype),
                     new_kv.astype(cache_kv.dtype))
    written = jnp.any(oh, axis=1)                              # [B,S]
    return jnp.where(written[None, :, :, None, None], upd, cache_kv)


def _page_dest(block_tables, positions, keep, page_size: int, n_pages: int):
    """Flat page-pool destinations [B,T] for absolute token positions.

    ``keep`` masks live entries; everything else maps to the out-of-bounds
    sentinel ``n_pages * page_size`` so the scatter drops it.  Page indices
    are clipped into the table so padded rows (table width > pages owned)
    never index out of bounds — their ``keep`` is False anyway.
    """
    W = block_tables.shape[1]
    pidx = jnp.clip(positions // page_size, 0, W - 1)
    page = jnp.take_along_axis(block_tables, pidx, axis=1)
    return jnp.where(keep, page * page_size + positions % page_size,
                     n_pages * page_size)


def _scatter_pages(pages, new, dest):
    """pages [L,P,ps,KVH,hd] ← new [L,B,T,KVH,hd] at flat dest [B,T].

    Token-granular scatter into the paged pool.  Distinct live destinations
    never collide (each (page, offset) is owned by one request position);
    dropped entries all share the OOB sentinel.
    """
    L, P, ps, KVH, hd = pages.shape
    flat = pages.reshape(L, P * ps, KVH, hd)
    flat = flat.at[:, dest.reshape(-1)].set(
        new.astype(pages.dtype).reshape(L, -1, KVH, hd), mode="drop")
    return flat.reshape(L, P, ps, KVH, hd)


def _scatter_paged(pages, new, dest, kv_shard=None):
    """Page-pool scatter, dispatching to the shard-local variant when the
    pool is sharded (``kv_shard``: a ``KVShardSpec``) — each shard drops
    out-of-shard destinations so no KV crosses the kv axis and XLA keeps
    aliasing the per-shard pool buffers (donation).

    COW safety: the scatter itself never needs to know about sharing —
    the allocator's ``ensure_private`` runs *before* any dispatch that
    writes a page, so by the time destinations reach here every written
    page is refcount-1 and unregistered.  The page-granular movement
    primitives live below (:func:`copy_pages`, :func:`write_pages`)."""
    if kv_shard is None:
        return _scatter_pages(pages, new, dest)
    from repro.distributed.collectives import scatter_pages_sharded
    return scatter_pages_sharded(pages, new, dest, kv_shard)


def copy_pages(cache, src, dst):
    """Whole-page device-side copy: ``cache[name][:, dst[i]] ←
    cache[name][:, src[i]]`` for every pool array in ``cache``.

    This is the copy-on-write kernel: callers jit it with
    ``donate_argnums=(0,)`` so the pool buffers alias in place (same
    donation contract as the decode scatter).  All gathers read the
    *input* array before any scatter lands, so chained src/dst overlaps
    within one batched call are safe; duplicate (src, dst) pairs (the
    pow-2 index padding) are idempotent."""
    return {name: arr.at[:, dst].set(arr[:, src])
            for name, arr in cache.items()}


def write_pages(cache, dst, k_new, v_new):
    """Whole-page host→device swap-in scatter: page ``dst[i]`` of the pool
    receives ``k_new[:, i]`` / ``v_new[:, i]`` ([L, n, ps, KVH, hd]).
    Jitted with ``donate_argnums=(0,)`` by the allocator so the pool
    aliases in place; duplicate padded indices write identical data."""
    k, v = cache["k_pages"], cache["v_pages"]
    return {"k_pages": k.at[:, dst].set(k_new.astype(k.dtype)),
            "v_pages": v.at[:, dst].set(v_new.astype(v.dtype))}


class TransformerLM:
    """Family-dispatching decoder-only LM."""

    def __init__(self, cfg: ArchConfig):
        assert cfg.family in ("dense", "moe", "hybrid", "ssm", "vlm"), cfg.family
        self.cfg = cfg
        if cfg.family == "hybrid":
            assert cfg.attn_period > 0 and cfg.n_layers % cfg.attn_period == 0
            self.n_periods = cfg.n_layers // cfg.attn_period
        else:
            self.n_periods = cfg.n_layers

    # ------------------------------------------------------------------
    # Layer-position structure
    # ------------------------------------------------------------------
    def _positions(self):
        """(mixer, ffn) kinds for each position inside one scan step."""
        cfg = self.cfg
        if cfg.family == "hybrid":
            out = []
            for j in range(cfg.attn_period):
                mixer = "attn" if cfg.is_attn_layer(j) else "mamba"
                ffn = "moe" if cfg.is_moe_layer(j) else "mlp"
                out.append((mixer, ffn))
            return out
        if cfg.family == "ssm":
            return [("rwkv_tm", "rwkv_cm")]
        mixer = "attn"
        ffn = "moe" if cfg.n_experts > 0 else "mlp"
        return [(mixer, ffn)]

    def attn_positions(self):
        return [j for j, (m, _) in enumerate(self._positions()) if m == "attn"]

    @property
    def has_kv(self):
        return bool(self.attn_positions())

    _MIXER_INIT = {
        "attn": (init_attention, axes_attention),
        "mamba": (ssm.init_mamba, ssm.axes_mamba),
        "rwkv_tm": (ssm.init_rwkv_timemix, ssm.axes_rwkv_timemix),
    }
    _FFN_INIT = {
        "mlp": (init_mlp, axes_mlp),
        "moe": (init_moe, axes_moe),
        "rwkv_cm": (ssm.init_rwkv_chanmix, ssm.axes_rwkv_chanmix),
    }

    # ------------------------------------------------------------------
    # Init
    # ------------------------------------------------------------------
    def init(self, rng, abstract: bool = False):
        cfg = self.cfg
        kg = KeyGen(rng)
        n = self.n_periods
        blocks = {}
        for j, (mixer, ffn) in enumerate(self._positions()):
            mi, _ = self._MIXER_INIT[mixer]
            fi, _ = self._FFN_INIT[ffn]
            blocks[f"pos{j}"] = {
                "norm1": _stack_init(init_norm, kg, cfg, n, abstract),
                "mixer": _stack_init(mi, kg, cfg, n, abstract),
                "norm2": _stack_init(init_norm, kg, cfg, n, abstract),
                "ffn": _stack_init(fi, kg, cfg, n, abstract),
            }
        params = {
            "embed": embed_init_a(kg(), (cfg.vocab_size, cfg.d_model), cfg.pdt,
                                  abstract=abstract),
            "blocks": blocks,
            "final_norm": init_norm(kg, cfg, abstract=abstract),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init_a(kg(), (cfg.d_model, cfg.vocab_size),
                                             cfg.pdt, abstract=abstract)
        return params

    def logical_axes(self):
        cfg = self.cfg
        blocks = {}
        for j, (mixer, ffn) in enumerate(self._positions()):
            _, ma = self._MIXER_INIT[mixer]
            _, fa = self._FFN_INIT[ffn]
            blocks[f"pos{j}"] = {
                "norm1": _stack_axes(axes_norm, cfg),
                "mixer": _stack_axes(ma, cfg),
                "norm2": _stack_axes(axes_norm, cfg),
                "ffn": _stack_axes(fa, cfg),
            }
        axes = {
            "embed": ("vocab_p", "embed_p"),
            "blocks": blocks,
            "final_norm": axes_norm(cfg),
        }
        if not cfg.tie_embeddings:
            axes["lm_head"] = ("embed_p", "vocab_p")
        return axes

    # ------------------------------------------------------------------
    # Embedding / head
    # ------------------------------------------------------------------
    def embed(self, params, tokens, mm_embeds=None, mm_mask=None):
        cfg = self.cfg
        x = params["embed"].astype(cfg.cdt)[tokens]
        if mm_embeds is not None:
            x = jnp.where(mm_mask[..., None], mm_embeds.astype(cfg.cdt), x)
        return shard(x, "batch", "seq", "embed")

    def head(self, params, x):
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = (x @ w.astype(cfg.cdt)).astype(jnp.float32)
        return shard(logits, "batch", "seq", "vocab")

    # ------------------------------------------------------------------
    # Core scanned stack
    # ------------------------------------------------------------------
    def _mixer_apply(self, kind, p, x, positions, shared, lx):
        """Returns (y, kv_or_none, new_state_or_none)."""
        cfg = self.cfg
        if kind == "attn":
            q, k, v = qkv_project(p, cfg, x, positions)
            pos1d = positions if positions.ndim == 2 else positions[:, 0, :]
            parts = []
            if "cache_k" in lx:
                kc = lx["cache_k"].astype(cfg.cdt)
                vc = lx["cache_v"].astype(cfg.cdt)
                B, S = kc.shape[0], kc.shape[1]
                k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
                parts.append(flash_partial(
                    q, kc, vc, q_pos=pos1d, k_pos=k_pos,
                    k_valid=k_pos < shared["cache_len"][:, None], kind="all"))
            if "page_k" in lx:
                # paged prefix: block-table-indirected flash partial over the
                # page pool (Pallas chunked-paged-attention kernel, or the
                # pure-jnp oracle when paged_attn_impl == "ref").  With a
                # sharded pool the partial is computed split-KV over the kv
                # mesh axis — each shard attends over its local pages only
                # and the partials merge exactly (pmax/psum) on device.
                kp = lx["page_k"].astype(cfg.cdt)
                vp = lx["page_v"].astype(cfg.cdt)
                ks = shared.get("kv_shard")
                if ks is not None:
                    from repro.distributed.collectives import \
                        split_kv_paged_partial
                    parts.append(split_kv_paged_partial(
                        q, kp, vp, shared["block_tables"],
                        shared["ctx_lens"], shared["shard_offs"], ks,
                        impl=shared["paged_impl"],
                        interpret=shared["paged_interpret"]))
                elif shared["paged_impl"] == "ref":
                    parts.append(kernel_ref.paged_chunk_ref(
                        q, kp, vp, shared["block_tables"],
                        shared["ctx_lens"]))
                else:
                    parts.append(paged_chunk_attention_kernel(
                        q, kp, vp, shared["block_tables"],
                        shared["ctx_lens"],
                        interpret=shared["paged_interpret"]))
            if "self_flash" in shared:
                sf = shared["self_flash"]
                B, T = pos1d.shape
                if sf.get("aligned") and sf["kind"] in ("causal",
                                                        "block_causal"):
                    # triangular flash: statically skips fully-masked
                    # above-diagonal chunk pairs (≈2× attention FLOPs)
                    parts.append(flash_partial_aligned(
                        q, k, v, lengths=sf["lengths"], kind=sf["kind"],
                        block_size=cfg.block_size))
                else:
                    parts.append(flash_partial(
                        q, k, v, q_pos=pos1d, k_pos=pos1d,
                        k_valid=jnp.arange(T)[None, :] < sf["lengths"][:, None],
                        kind=sf["kind"], block_size=cfg.block_size))
            else:
                parts.append(sdpa_partial(q, k, v, shared["self_mask"]))
            out = combine_partials(parts, x.dtype)
            return attn_output(p, cfg, out), (k, v), None
        if kind == "mamba":
            y, st = ssm.mamba_seq(p, cfg, x, lx["state"])
            return y, None, st
        if kind == "rwkv_tm":
            y, st = ssm.rwkv_timemix(p, cfg, x, lx["state"])
            return y, None, st
        raise ValueError(kind)

    def _ffn_apply(self, kind, p, x, lx):
        cfg = self.cfg
        if kind == "mlp":
            return mlp_block(p, cfg, x), None
        if kind == "moe":
            return moe_block(p, cfg, x), None
        if kind == "rwkv_cm":
            return ssm.rwkv_chanmix(p, cfg, x, lx["state"])
        raise ValueError(kind)

    def _stack(self, params, x, positions, shared, per_layer_xs):
        """Run the scanned layer stack.

        ``shared``: masks closed over (same for every layer).
        ``per_layer_xs``: pytree whose leaves have leading dim n_periods —
        attention cache slices and recurrent states per position.
        Returns (x, kvs, states): kvs/states keyed by position, leaves with
        leading n_periods dim.
        """
        cfg = self.cfg
        pos_kinds = self._positions()

        def body(x, inp):
            blk, lxs = inp
            kv_out, state_out = {}, {}
            for j, (mixer, ffn) in enumerate(pos_kinds):
                p = blk[f"pos{j}"]
                lx = lxs.get(f"pos{j}", {})
                h = rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
                y, kv, st = self._mixer_apply(mixer, p["mixer"], h, positions,
                                              shared, lx)
                x = x + y
                h = rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
                y, fst = self._ffn_apply(ffn, p["ffn"], h,
                                         lxs.get(f"ffn{j}", {}))
                x = x + y
                if kv is not None:
                    kv_out[f"pos{j}"] = kv
                if st is not None:
                    state_out[f"pos{j}"] = st
                if fst is not None:
                    state_out[f"ffn{j}"] = fst
            return x, (kv_out, state_out)

        if cfg.remat:
            body = jax.checkpoint(body)

        x, (kvs, states) = jax.lax.scan(body, x, (params["blocks"],
                                                  per_layer_xs))
        return x, kvs, states

    # ------------------------------------------------------------------
    # Recurrent-state helpers
    # ------------------------------------------------------------------
    def _fresh_states(self, kind, B, dtype):
        cfg = self.cfg
        n = self.n_periods

        def stackit(st):
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n,) + a.shape).astype(a.dtype), st)

        if kind == "mamba":
            return stackit(ssm.mamba_init_state(cfg, B, dtype))
        if kind == "rwkv_tm":
            st = ssm.rwkv_init_state(cfg, B, dtype)
            return stackit({"tm_prev": st["tm_prev"], "wkv": st["wkv"]})
        if kind == "rwkv_cm":
            st = ssm.rwkv_init_state(cfg, B, dtype)
            return stackit({"cm_prev": st["cm_prev"]})
        raise ValueError(kind)

    def _state_xs(self, B, dtype, cache=None):
        """Per-layer recurrent-state xs (fresh, or read from cache)."""
        out = {}
        for j, (mixer, ffn) in enumerate(self._positions()):
            if mixer in ("mamba", "rwkv_tm"):
                out[f"pos{j}"] = {"state":
                                  cache["states"][f"pos{j}"] if cache else
                                  self._fresh_states(mixer, B, dtype)}
            if ffn == "rwkv_cm":
                out[f"ffn{j}"] = {"state":
                                  cache["states"][f"ffn{j}"] if cache else
                                  self._fresh_states("rwkv_cm", B, dtype)}
        return out

    def _cache_xs(self, cache):
        """Per-layer attention-cache xs."""
        out = {}
        if self.has_kv and cache is not None and "k" in cache:
            for j in self.attn_positions():
                out[f"pos{j}"] = {"cache_k": cache["k"], "cache_v": cache["v"]}
        return out

    def _collect_kv(self, kvs):
        """kvs from scan → stacked [L_attn, B, T, KVH, hd] k and v."""
        ks = [kvs[f"pos{j}"][0] for j in self.attn_positions()]
        vs = [kvs[f"pos{j}"][1] for j in self.attn_positions()]
        if not ks:
            return None
        # each is [n_periods, B, T, KVH, hd]; one attn per period in all archs
        return {"k": ks[0], "v": vs[0]} if len(ks) == 1 else \
            {"k": jnp.concatenate(ks, 0), "v": jnp.concatenate(vs, 0)}

    # ------------------------------------------------------------------
    # Public entry points
    # ------------------------------------------------------------------
    def apply(self, params, tokens, positions=None, mask_mode="causal",
              lengths=None, mm_embeds=None, mm_mask=None):
        """Full forward → logits [B,T,V] (training path)."""
        cfg = self.cfg
        B, T = tokens.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        kind = {"causal": "causal", "block_causal": "block_causal",
                "bidirectional": "all"}[mask_mode]
        if lengths is None:
            lengths = jnp.full((B,), T, jnp.int32)
        aligned = positions.ndim == 2 and positions.shape == (B, T)
        shared = {"self_flash": {"kind": kind, "lengths": lengths,
                                 "aligned": True}}
        x = self.embed(params, tokens, mm_embeds, mm_mask)
        per_layer = self._state_xs(B, x.dtype)
        x, _, _ = self._stack(params, x, positions, shared, per_layer)
        return self.head(params, x)

    # -- serving ---------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        cache: dict[str, Any] = {"len": jnp.zeros((batch,), jnp.int32)}
        n_attn_stack = len(self.attn_positions()) * self.n_periods
        if n_attn_stack:
            shp = (self.n_periods * len(self.attn_positions()), batch,
                   max_len, cfg.n_kv_heads, cfg.hd)
            cache["k"] = jnp.zeros(shp, dtype)
            cache["v"] = jnp.zeros(shp, dtype)
        states = self._state_xs(batch, dtype)
        if states:
            cache["states"] = {k: v["state"] for k, v in states.items()}
        return cache

    def cache_logical_axes(self, cache):
        """Logical axes for the cache pytree (kv_seq enables split-KV)."""
        def one(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            if name in ("k", "v"):
                return ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
            if name in ("k_pages", "v_pages"):   # page dim sharded for
                return ("layers", "kv_pages", None,  # split-KV paged decode
                        "kv_heads", "head_dim")      # (kv_shard_rules)
            if name == "len":
                return ("batch",)
            if name == "wkv":
                return ("layers", "batch", "heads", None, None)
            if name == "conv":                 # [L, B, d_conv-1, d_inner]
                return ("layers", "batch", None, "mlp")
            if name == "ssm":                  # [L, B, d_inner, d_state]
                return ("layers", "batch", "mlp", None)
            return ("layers", "batch") + (None,) * (leaf.ndim - 2)
        return jax.tree_util.tree_map_with_path(one, cache)

    def prefill(self, params, tokens, lengths, cache, positions=None,
                mask_mode=None, mm_embeds=None, mm_mask=None,
                head_mode="all"):
        """Forward prompt, writing KV/state cache. Returns (logits, cache).

        head_mode: "all" → logits for every position (tests); "last" →
        only the last valid position (serving — avoids the T×V logits
        blow-up at 32k prefill); "none" → no logits.
        """
        cfg = self.cfg
        B, T = tokens.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        if mask_mode is None:
            mask_mode = "block_causal" if cfg.diffusion else "causal"
        pos1d = positions if positions.ndim == 2 else positions[:, 0, :]
        shared = {"self_flash": {"kind": mask_mode, "lengths": lengths,
                                 "aligned": positions is not None}}
        x = self.embed(params, tokens, mm_embeds, mm_mask)
        per_layer = self._state_xs(B, x.dtype)
        x, kvs, states = self._stack(params, x, positions, shared, per_layer)
        if head_mode == "last":
            idx = jnp.clip(lengths - 1, 0, T - 1)
            xl = jnp.take_along_axis(
                x, idx[:, None, None].astype(jnp.int32), axis=1)
            logits = self.head(params, xl)
        elif head_mode == "none":
            logits = None
        else:
            logits = self.head(params, x)

        new_cache = dict(cache)
        kv = self._collect_kv(kvs)
        if kv is not None and "k" in cache:
            # Admission fills positions [0, T) wholesale: mask + pad instead
            # of scatter (dynamic scatter onto the sharded cache triggers
            # XLA SPMD's involuntary full rematerialization → cache-sized
            # replicated temporaries at 32k prefill).
            S = cache["k"].shape[2]
            keep = (jnp.arange(T)[None, :] < lengths[:, None])

            def place(new, old):
                x = jnp.where(keep[None, :, :, None, None], new, 0)
                x = x.astype(old.dtype)
                if S > T:
                    x = jnp.pad(x, ((0, 0), (0, 0), (0, S - T), (0, 0),
                                    (0, 0)))
                return shard(x, "layers", "batch", "kv_seq", "kv_heads",
                             "head_dim")

            new_cache["k"] = place(kv["k"], cache["k"])
            new_cache["v"] = place(kv["v"], cache["v"])
        if states:
            new_cache["states"] = states
        new_cache["len"] = lengths.astype(jnp.int32)
        return logits, new_cache

    def _window_masks(self, cache, positions, valid, c):
        cfg = self.cfg
        if cfg.diffusion:
            sm = block_causal_mask(positions, positions, cfg.block_size)
        else:
            sm = causal_mask(positions, positions)
        sm = sm & valid[:, None, :] & valid[:, :, None]
        sm = sm | jnp.eye(c, dtype=bool)[None]
        shared = {"self_mask": sm[:, None]}
        if self.has_kv and "k" in cache:
            shared["cache_len"] = cache["len"]
        return shared

    def chunk_forward(self, params, cache, win_tokens, win_start, win_valid,
                      mm_embeds=None, mm_mask=None):
        """Diffusion-window forward.

        win_tokens [B,c] (mask token at uncommitted positions),
        win_start [B] (== cache['len'] for sliding-window families),
        win_valid [B] (#valid window slots, for in-block clamping).
        Returns (logits [B,c,V], win_kv {"k": [L_attn,B,c,KVH,hd], ...}).
        """
        B, c = win_tokens.shape
        offs = jnp.arange(c, dtype=jnp.int32)
        positions = win_start[:, None] + offs[None, :]
        valid = offs[None, :] < win_valid[:, None]
        shared = self._window_masks(cache, positions, valid, c)
        per_layer = {**self._cache_xs(cache),
                     **self._state_xs(B, self.cfg.cdt, cache=cache
                                      if "states" in cache else None)}
        x = self.embed(params, win_tokens, mm_embeds, mm_mask)
        x, kvs, _ = self._stack(params, x, positions, shared, per_layer)
        logits = self.head(params, x)
        return logits, self._collect_kv(kvs)

    def freeze(self, cache, win_kv, win_start, n_adv):
        """Write the first n_adv[b] window KV entries into the cache and
        advance ``len``.  Sliding-window (attention-only) families only."""
        new_cache = dict(cache)
        if win_kv is not None and "k" in cache:
            c = win_kv["k"].shape[2]
            S = cache["k"].shape[2]
            offs = jnp.arange(c, dtype=jnp.int32)
            keep = offs[None, :] < n_adv[:, None]
            idx = jnp.where(keep, win_start[:, None] + offs[None, :], S)
            new_cache["k"] = _scatter_kv(cache["k"], win_kv["k"], idx)
            new_cache["v"] = _scatter_kv(cache["v"], win_kv["v"], idx)
        new_cache["len"] = cache["len"] + n_adv.astype(jnp.int32)
        return new_cache

    # -- paged serving ---------------------------------------------------
    #
    # The paged cache variant replaces the dense per-slot [L,B,S,KVH,hd]
    # arrays with a block-table-indirected page pool [L,P,ps,KVH,hd] shared
    # by every in-flight request (NanoFlow-style: capacity is bounded by
    # pages, not slots).  Supported for attention-only families
    # (dense/moe/vlm); recurrent families keep the dense-slot path.

    PAGED_FAMILIES = ("dense", "moe", "vlm")

    def supports_paged(self) -> bool:
        return self.cfg.family in self.PAGED_FAMILIES and self.has_kv

    def _check_paged(self):
        if not self.supports_paged():
            raise ValueError(
                f"paged KV serving needs an attention-only family "
                f"(got {self.cfg.family!r})")

    def paged_kv_dims(self) -> tuple[int, int, int]:
        """(n_kv_layers, n_kv_heads, head_dim) — the model-derived half of
        the page-pool shape.  Single source for both
        :meth:`init_paged_cache` and ``PagedKVAllocator.init_storage``."""
        return (self.n_periods * len(self.attn_positions()),
                self.cfg.n_kv_heads, self.cfg.hd)

    def init_paged_cache(self, n_pages: int, page_size: int | None = None,
                         dtype=jnp.float32):
        """Page-pool cache: {'k_pages','v_pages'} [L,P,ps,KVH,hd] (the same
        arrays ``PagedKVAllocator.init_storage`` owns in serving)."""
        self._check_paged()
        ps = page_size if page_size is not None else self.cfg.kv_page_size
        L, KVH, hd = self.paged_kv_dims()
        shp = (L, n_pages, ps, KVH, hd)
        return {"k_pages": jnp.zeros(shp, dtype),
                "v_pages": jnp.zeros(shp, dtype)}

    def prefill_paged(self, params, cache, tokens, lengths, block_tables,
                      mm_embeds=None, mm_mask=None, head_mode="logits",
                      kv_shard=None):
        """Batched prompt forward writing KV into the page pool.

        tokens [B,T] (row-padded), lengths [B], block_tables [B,W] int32.
        The whole admission wave runs as ONE forward, unlike the dense
        path's sequential per-slot prefill.

        head_mode (static): "logits" returns the last-valid-position logits
        [B,V]; "sample" reduces them on device via
        :func:`repro.kernels.ops.softmax_confidence_device` and returns
        (conf [B], tok [B]) — only AR requests ever read the prefill head,
        and they need just the argmax, so serving never ships [B,V] logits
        to the host.  Returns (head output, new page cache).

        ``kv_shard`` (static ``KVShardSpec`` or None): sharded page pool —
        the KV scatter stays shard-local (block tables carry GLOBAL page
        ids; each shard drops pages it doesn't own).
        """
        self._check_paged()
        cfg = self.cfg
        B, T = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        mask_mode = "block_causal" if cfg.diffusion else "causal"
        shared = {"self_flash": {"kind": mask_mode, "lengths": lengths,
                                 "aligned": True}}
        x = self.embed(params, tokens, mm_embeds, mm_mask)
        x, kvs, _ = self._stack(params, x, positions, shared, {})
        idx = jnp.clip(lengths - 1, 0, T - 1)
        xl = jnp.take_along_axis(
            x, idx[:, None, None].astype(jnp.int32), axis=1)
        logits = self.head(params, xl)[:, 0]
        kv = self._collect_kv(kvs)
        P, ps = cache["k_pages"].shape[1], cache["k_pages"].shape[2]
        keep = positions < lengths[:, None]
        dest = _page_dest(block_tables, positions, keep, ps, P)
        new_cache = {
            "k_pages": _scatter_paged(cache["k_pages"], kv["k"], dest,
                                      kv_shard),
            "v_pages": _scatter_paged(cache["v_pages"], kv["v"], dest,
                                      kv_shard)}
        if head_mode == "sample":
            from repro.kernels.ops import softmax_confidence_device
            conf, tok = softmax_confidence_device(logits)
            return (conf, tok), new_cache
        return logits, new_cache

    def prefill_chunk_paged(self, params, cache, tokens, offsets, valid,
                            block_tables, *, impl: str = "kernel",
                            interpret=None, mm_embeds=None, mm_mask=None,
                            kv_shard=None, shard_offs=None):
        """One resumable prefill chunk per row: forward prompt tokens
        [offsets, offsets + valid) against the pages already written by
        earlier chunks, and scatter this chunk's KV into the pool.

        tokens [B,T] (row-padded chunk tokens), offsets [B] absolute chunk
        start, valid [B] live tokens per row (0 ⇒ padded row, no writes).
        The already-prefilled prefix is read through ``block_tables`` with
        ``ctx_lens = offsets`` — the same paged-prefix partial the decode
        windows use — and the in-window part applies the prefill mask
        (block-causal for diffusion, causal otherwise) over absolute
        positions.  Diffusion chunk boundaries must be block-aligned (a
        mid-block split would hide the block's unprefilled tail from its
        head, diverging from the wave forward); the serving-side
        :class:`~repro.serving.backends.PrefillScheduler` guarantees this.

        Returns (conf [B], tok [B], new page cache): the last-valid-position
        head reduced on device — meaningful only for rows whose prompt
        completes with this chunk (the AR first token), never [B,V] logits.
        """
        from repro.kernels.ops import softmax_confidence_device
        self._check_paged()
        B, T = tokens.shape
        offs = jnp.arange(T, dtype=jnp.int32)
        positions = offsets[:, None] + offs[None, :]
        validm = offs[None, :] < valid[:, None]
        shared = self._window_masks(cache, positions, validm, T)
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        shared.update(block_tables=block_tables.astype(jnp.int32),
                      ctx_lens=offsets.astype(jnp.int32),
                      paged_impl=impl, paged_interpret=interpret)
        self._shared_kv_shard(shared, kv_shard, shard_offs, B)
        per_layer = {f"pos{j}": {"page_k": cache["k_pages"],
                                 "page_v": cache["v_pages"]}
                     for j in self.attn_positions()}
        x = self.embed(params, tokens, mm_embeds, mm_mask)
        x, kvs, _ = self._stack(params, x, positions, shared, per_layer)
        idx = jnp.clip(valid - 1, 0, T - 1)
        xl = jnp.take_along_axis(
            x, idx[:, None, None].astype(jnp.int32), axis=1)
        logits = self.head(params, xl)[:, 0]
        kv = self._collect_kv(kvs)
        P, ps = cache["k_pages"].shape[1], cache["k_pages"].shape[2]
        dest = _page_dest(block_tables, positions, validm, ps, P)
        new_cache = {
            "k_pages": _scatter_paged(cache["k_pages"], kv["k"], dest,
                                      kv_shard),
            "v_pages": _scatter_paged(cache["v_pages"], kv["v"], dest,
                                      kv_shard)}
        conf, tok = softmax_confidence_device(logits)
        return conf, tok, new_cache

    @staticmethod
    def _shared_kv_shard(shared, kv_shard, shard_offs, B):
        """Install the sharded-pool fields read by ``_mixer_apply``'s
        paged branch (no-op when the pool is unsharded)."""
        if kv_shard is None:
            return
        if shard_offs is None:
            shard_offs = jnp.zeros((B,), jnp.int32)
        shared.update(kv_shard=kv_shard,
                      shard_offs=shard_offs.astype(jnp.int32))

    def chunk_forward_paged(self, params, cache, win_tokens, win_start,
                            win_valid, block_tables, ctx_lens, *,
                            impl: str = "kernel", interpret=None,
                            mm_embeds=None, mm_mask=None,
                            kv_shard=None, shard_offs=None):
        """Diffusion-window forward against the paged prefix cache.

        Same contract as :meth:`chunk_forward`, but the frozen prefix is
        read through block tables: ``impl='kernel'`` runs the Pallas
        chunked-paged-attention kernel (interpret mode off-TPU),
        ``impl='ref'`` the pure-jnp oracle.  ctx_lens [B] is the committed
        prefix length per row (0 for padded rows — their paged partial is
        empty and the in-window diagonal keeps logits finite).
        """
        self._check_paged()
        B, c = win_tokens.shape
        offs = jnp.arange(c, dtype=jnp.int32)
        positions = win_start[:, None] + offs[None, :]
        valid = offs[None, :] < win_valid[:, None]
        shared = self._window_masks(cache, positions, valid, c)
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        shared.update(block_tables=block_tables.astype(jnp.int32),
                      ctx_lens=ctx_lens.astype(jnp.int32),
                      paged_impl=impl, paged_interpret=interpret)
        self._shared_kv_shard(shared, kv_shard, shard_offs, B)
        per_layer = {f"pos{j}": {"page_k": cache["k_pages"],
                                 "page_v": cache["v_pages"]}
                     for j in self.attn_positions()}
        x = self.embed(params, win_tokens, mm_embeds, mm_mask)
        x, kvs, _ = self._stack(params, x, positions, shared, per_layer)
        logits = self.head(params, x)
        return logits, self._collect_kv(kvs)

    def freeze_paged(self, cache, win_kv, block_tables, win_start, n_adv,
                     kv_shard=None):
        """Write the first n_adv[b] window KV entries into the page pool
        (the paged counterpart of :meth:`freeze`; 'len' lives with the
        caller's decode state, not in the cache)."""
        c = win_kv["k"].shape[2]
        P, ps = cache["k_pages"].shape[1], cache["k_pages"].shape[2]
        offs = jnp.arange(c, dtype=jnp.int32)
        pos = win_start[:, None] + offs[None, :]
        keep = offs[None, :] < n_adv[:, None]
        dest = _page_dest(block_tables, pos, keep, ps, P)
        return {"k_pages": _scatter_paged(cache["k_pages"], win_kv["k"],
                                          dest, kv_shard),
                "v_pages": _scatter_paged(cache["v_pages"], win_kv["v"],
                                          dest, kv_shard)}

    def decode_step_paged(self, params, cache, win_tokens, win_start,
                          win_valid, block_tables, ctx_lens, n_adv, *,
                          impl: str = "kernel", interpret=None,
                          mm_embeds=None, mm_mask=None,
                          kv_shard=None, shard_offs=None):
        """One fused paged decode iteration: chunk-forward + freeze +
        on-device sampling in a single dispatch.

        Composes :meth:`chunk_forward_paged`, :meth:`freeze_paged` and the
        device softmax-confidence/argmax reduction
        (:func:`repro.kernels.ops.softmax_confidence_device`) so one jitted
        call per step replaces the chunk + freeze pair, and only
        ``2·B·c`` scalars (confidence fp32, token int32) return to the
        host instead of the full ``[B, c, V]`` logits.

        ``n_adv`` [B] is the number of leading window KV entries to freeze
        — precomputable before the step for slide-mode windows (the leading
        committed-at-input run; see :func:`repro.core.chunked.freeze_run`)
        and always 1 for AR rows.  Jit with ``donate_argnums=(1,)`` so the
        page pool aliases in place instead of being copied every step.
        Returns (conf [B, c], tok [B, c], new page cache).
        """
        from repro.kernels.ops import softmax_confidence_device
        logits, win_kv = self.chunk_forward_paged(
            params, cache, win_tokens, win_start, win_valid, block_tables,
            ctx_lens, impl=impl, interpret=interpret,
            mm_embeds=mm_embeds, mm_mask=mm_mask,
            kv_shard=kv_shard, shard_offs=shard_offs)
        new_cache = self.freeze_paged(cache, win_kv, block_tables,
                                      win_start, n_adv, kv_shard=kv_shard)
        conf, tok = softmax_confidence_device(logits)
        return conf, tok, new_cache

    def advance_states(self, params, cache, tokens, lengths,
                       mm_embeds=None, mm_mask=None):
        """Advance recurrent states (and attention KV) over committed
        ``tokens`` [B,T] starting at cache['len'].  Returns (logits, cache)."""
        B, T = tokens.shape
        start = cache["len"]
        offs = jnp.arange(T, dtype=jnp.int32)
        positions = start[:, None] + offs[None, :]
        valid = offs[None, :] < lengths[:, None]
        shared = self._window_masks(cache, positions, valid, T)
        per_layer = {**self._cache_xs(cache),
                     **self._state_xs(B, self.cfg.cdt, cache=cache
                                      if "states" in cache else None)}
        x = self.embed(params, tokens, mm_embeds, mm_mask)
        x, kvs, states = self._stack(params, x, positions, shared, per_layer)
        logits = self.head(params, x)

        new_cache = dict(cache)
        kv = self._collect_kv(kvs)
        if kv is not None and "k" in cache:
            S = cache["k"].shape[2]
            idx = jnp.where(valid, positions, S)
            new_cache["k"] = _scatter_kv(cache["k"], kv["k"], idx)
            new_cache["v"] = _scatter_kv(cache["v"], kv["v"], idx)
        if states:
            new_cache["states"] = states
        new_cache["len"] = cache["len"] + lengths.astype(jnp.int32)
        return logits, new_cache
