"""Encoder–decoder LM (seamless-m4t backbone).

The modality frontend is a STUB per the assignment: ``encode`` consumes
precomputed frame embeddings [B, S_src, d] directly.  The decoder is a
standard transformer decoder with self-attention + cross-attention; block
diffusion (and therefore Optimus chunked decoding) applies to the decoder
side, with cross-attention KV computed once at admission and cached.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.common import ArchConfig, KeyGen, dense_init_a, embed_init_a
from repro.models.layers import (attn_output, axes_attention, axes_mlp,
                                 axes_norm, block_causal_mask, causal_mask,
                                 combine_partials, flash_partial,
                                 init_attention, init_mlp, init_norm,
                                 mlp_block, qkv_project, rms_norm,
                                 sdpa_partial)
from repro.models.transformer import _scatter_kv, _stack_axes, _stack_init


class EncDecLM:
    def __init__(self, cfg: ArchConfig):
        assert cfg.family == "encdec"
        assert cfg.n_enc_layers > 0
        self.cfg = cfg
        self.n_periods = cfg.n_layers          # decoder depth (scan dim)

    # ------------------------------------------------------------------
    def init(self, rng, abstract: bool = False):
        cfg = self.cfg
        kg = KeyGen(rng)
        enc = {
            "norm1": _stack_init(init_norm, kg, cfg, cfg.n_enc_layers, abstract),
            "attn": _stack_init(init_attention, kg, cfg, cfg.n_enc_layers, abstract),
            "norm2": _stack_init(init_norm, kg, cfg, cfg.n_enc_layers, abstract),
            "mlp": _stack_init(init_mlp, kg, cfg, cfg.n_enc_layers, abstract),
        }
        dec = {
            "norm1": _stack_init(init_norm, kg, cfg, cfg.n_layers, abstract),
            "self_attn": _stack_init(init_attention, kg, cfg, cfg.n_layers, abstract),
            "norm_x": _stack_init(init_norm, kg, cfg, cfg.n_layers, abstract),
            "cross_attn": _stack_init(init_attention, kg, cfg, cfg.n_layers, abstract),
            "norm2": _stack_init(init_norm, kg, cfg, cfg.n_layers, abstract),
            "mlp": _stack_init(init_mlp, kg, cfg, cfg.n_layers, abstract),
        }
        return {
            "embed": embed_init_a(kg(), (cfg.vocab_size, cfg.d_model), cfg.pdt,
                                  abstract=abstract),
            "enc": enc,
            "enc_norm": init_norm(kg, cfg, abstract=abstract),
            "dec": dec,
            "final_norm": init_norm(kg, cfg, abstract=abstract),
            "lm_head": dense_init_a(kg(), (cfg.d_model, cfg.vocab_size),
                                    cfg.pdt, abstract=abstract),
        }

    def logical_axes(self):
        cfg = self.cfg
        return {
            "embed": ("vocab_p", "embed_p"),
            "enc": {"norm1": _stack_axes(axes_norm, cfg),
                    "attn": _stack_axes(axes_attention, cfg),
                    "norm2": _stack_axes(axes_norm, cfg),
                    "mlp": _stack_axes(axes_mlp, cfg)},
            "enc_norm": axes_norm(cfg),
            "dec": {"norm1": _stack_axes(axes_norm, cfg),
                    "self_attn": _stack_axes(axes_attention, cfg),
                    "norm_x": _stack_axes(axes_norm, cfg),
                    "cross_attn": _stack_axes(axes_attention, cfg),
                    "norm2": _stack_axes(axes_norm, cfg),
                    "mlp": _stack_axes(axes_mlp, cfg)},
            "final_norm": axes_norm(cfg),
            "lm_head": ("embed_p", "vocab_p"),
        }

    # ------------------------------------------------------------------
    def encode(self, params, src_embeds, src_mask):
        """Bidirectional encoder over precomputed frame embeddings."""
        cfg = self.cfg
        B, S, _ = src_embeds.shape
        x = shard(src_embeds.astype(cfg.cdt), "batch", "seq", "embed")
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        lengths = jnp.sum(src_mask.astype(jnp.int32), axis=-1)

        def body(x, blk):
            h = rms_norm(x, blk["norm1"]["scale"], cfg.norm_eps)
            q, k, v = qkv_project(blk["attn"], cfg, h, pos)
            acc, m, l = flash_partial(q, k, v, q_pos=pos, k_pos=pos,
                                      k_valid=src_mask, kind="all")
            out = combine_partials([(acc, m, l)], x.dtype)
            x = x + attn_output(blk["attn"], cfg, out)
            h = rms_norm(x, blk["norm2"]["scale"], cfg.norm_eps)
            return x + mlp_block(blk["mlp"], cfg, h), None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["enc"])
        return rms_norm(x, params["enc_norm"]["scale"], cfg.norm_eps)

    def _cross_kv(self, params, enc_out, pos):
        """Per-decoder-layer cross KV from encoder output (scan → stacked)."""
        cfg = self.cfg

        def body(_, blk):
            _, k, v = qkv_project(blk["cross_attn"], cfg, enc_out, pos)
            return None, (k, v)

        _, (ks, vs) = jax.lax.scan(body, None, params["dec"])
        return ks, vs                     # [L, B, S_src, KVH, hd]

    def _decoder(self, params, x, positions, shared, per_layer):
        cfg = self.cfg
        pos1d = positions

        def body(x, inp):
            blk, lx = inp
            h = rms_norm(x, blk["norm1"]["scale"], cfg.norm_eps)
            q, k, v = qkv_project(blk["self_attn"], cfg, h, pos1d)
            parts = []
            if "cache_k" in lx:
                kc = lx["cache_k"].astype(cfg.cdt)
                B, S = kc.shape[0], kc.shape[1]
                kp = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
                parts.append(flash_partial(
                    q, kc, lx["cache_v"].astype(cfg.cdt), q_pos=pos1d,
                    k_pos=kp, k_valid=kp < shared["cache_len"][:, None],
                    kind="all"))
            if "self_flash" in shared:
                sf = shared["self_flash"]
                T = pos1d.shape[1]
                parts.append(flash_partial(
                    q, k, v, q_pos=pos1d, k_pos=pos1d,
                    k_valid=jnp.arange(T)[None, :] < sf["lengths"][:, None],
                    kind=sf["kind"], block_size=cfg.block_size))
            else:
                parts.append(sdpa_partial(q, k, v, shared["self_mask"]))
            out = combine_partials(parts, x.dtype)
            x = x + attn_output(blk["self_attn"], cfg, out)

            # cross attention
            h = rms_norm(x, blk["norm_x"]["scale"], cfg.norm_eps)
            B, T, _ = h.shape
            hd = cfg.hd
            qx = (h @ blk["cross_attn"]["wq"].astype(cfg.cdt)) \
                .reshape(B, T, cfg.n_heads, hd)
            kx = lx["cross_k"].astype(cfg.cdt)
            vx = lx["cross_v"].astype(cfg.cdt)
            Ssrc = kx.shape[1]
            kp = jnp.broadcast_to(jnp.arange(Ssrc, dtype=jnp.int32), (B, Ssrc))
            acc, m, l = flash_partial(qx, kx, vx, q_pos=pos1d, k_pos=kp,
                                      k_valid=shared["src_mask"], kind="all")
            out = combine_partials([(acc, m, l)], x.dtype)
            x = x + attn_output(blk["cross_attn"], cfg, out)

            h = rms_norm(x, blk["norm2"]["scale"], cfg.norm_eps)
            x = x + mlp_block(blk["mlp"], cfg, h)
            return x, (k, v)

        if cfg.remat:
            body = jax.checkpoint(body)
        return jax.lax.scan(body, x, (params["dec"], per_layer))

    def head(self, params, x):
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        logits = (x @ params["lm_head"].astype(cfg.cdt)).astype(jnp.float32)
        return shard(logits, "batch", "seq", "vocab")

    # ------------------------------------------------------------------
    def apply(self, params, src_embeds, src_mask, tgt_tokens,
              mask_mode="causal", tgt_lengths=None):
        """Training forward: encode + teacher-forced decode → logits."""
        cfg = self.cfg
        B, T = tgt_tokens.shape
        enc_out = self.encode(params, src_embeds, src_mask)
        S_src = enc_out.shape[1]
        src_pos = jnp.broadcast_to(jnp.arange(S_src, dtype=jnp.int32), (B, S_src))
        ck, cv = self._cross_kv(params, enc_out, src_pos)
        pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        lengths = tgt_lengths if tgt_lengths is not None else \
            jnp.full((B,), T, jnp.int32)
        shared = {"self_flash": {"kind": mask_mode, "lengths": lengths},
                  "src_mask": src_mask}
        x = params["embed"].astype(cfg.cdt)[tgt_tokens]
        per_layer = {"cross_k": ck, "cross_v": cv}
        x, _ = self._decoder(params, x, pos, shared, per_layer)
        return self.head(params, x)

    # -- serving ---------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, src_len: int,
                   dtype=jnp.bfloat16):
        cfg = self.cfg
        shp = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
        xshp = (cfg.n_layers, batch, src_len, cfg.n_kv_heads, cfg.hd)
        return {
            "len": jnp.zeros((batch,), jnp.int32),
            "k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype),
            "cross_k": jnp.zeros(xshp, dtype), "cross_v": jnp.zeros(xshp, dtype),
            "src_mask": jnp.zeros((batch, src_len), bool),
        }

    def cache_logical_axes(self, cache):
        def one(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            if name in ("k", "v", "cross_k", "cross_v"):
                return ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
            if name in ("len",):
                return ("batch",)
            return ("batch",) + (None,) * (leaf.ndim - 1)
        return jax.tree_util.tree_map_with_path(one, cache)

    def admit(self, params, cache, src_embeds, src_mask):
        """Encode source and fill cross-attention KV (request admission)."""
        B = src_embeds.shape[0]
        enc_out = self.encode(params, src_embeds, src_mask)
        S_src = enc_out.shape[1]
        pos = jnp.broadcast_to(jnp.arange(S_src, dtype=jnp.int32), (B, S_src))
        ck, cv = self._cross_kv(params, enc_out, pos)
        new = dict(cache)
        new["cross_k"] = ck.astype(cache["cross_k"].dtype)
        new["cross_v"] = cv.astype(cache["cross_v"].dtype)
        new["src_mask"] = src_mask
        new["len"] = jnp.zeros((B,), jnp.int32)
        return new

    def chunk_forward(self, params, cache, win_tokens, win_start, win_valid):
        cfg = self.cfg
        B, c = win_tokens.shape
        offs = jnp.arange(c, dtype=jnp.int32)
        positions = win_start[:, None] + offs[None, :]
        valid = offs[None, :] < win_valid[:, None]
        if cfg.diffusion:
            sm = block_causal_mask(positions, positions, cfg.block_size)
        else:
            sm = causal_mask(positions, positions)
        sm = (sm & valid[:, None, :] & valid[:, :, None]) | \
            jnp.eye(c, dtype=bool)[None]
        shared = {"self_mask": sm[:, None], "cache_len": cache["len"],
                  "src_mask": cache["src_mask"]}
        per_layer = {"cache_k": cache["k"], "cache_v": cache["v"],
                     "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
        x = params["embed"].astype(cfg.cdt)[win_tokens]
        x, (ks, vs) = self._decoder(params, x, positions, shared, per_layer)
        logits = self.head(params, x)
        return logits, {"k": ks, "v": vs}

    def freeze(self, cache, win_kv, win_start, n_adv):
        new_cache = dict(cache)
        c = win_kv["k"].shape[2]
        S = cache["k"].shape[2]
        offs = jnp.arange(c, dtype=jnp.int32)
        keep = offs[None, :] < n_adv[:, None]
        idx = jnp.where(keep, win_start[:, None] + offs[None, :], S)
        new_cache["k"] = _scatter_kv(cache["k"], win_kv["k"], idx)
        new_cache["v"] = _scatter_kv(cache["v"], win_kv["v"], idx)
        new_cache["len"] = cache["len"] + n_adv.astype(jnp.int32)
        return new_cache
