"""Mixture-of-Experts FFN.

Two execution paths with identical semantics (modulo capacity dropping):

* ``moe_block_dense`` — one-hot einsum over all experts.  O(E·T·d·f) compute;
  only used as the oracle for tests and for tiny smoke configs.
* ``moe_block_sharded`` — production path: token-choice top-k routing, tokens
  sorted by expert id, per-expert grouped GEMM via ``lax.ragged_dot``, local
  experts per model-shard, partial outputs combined with ``psum``.  Runs under
  ``jax.shard_map`` (experts sharded over the ``model`` mesh axis, tokens over
  the data axes).  With a 1-device mesh it degenerates to the single-device
  sort-based path, which is also what unit tests exercise.

Token dropping: each model shard accepts at most
``capacity = ceil(T_local * top_k / n_model_shards * capacity_factor)``
(token, expert) pairs; overflow is dropped (standard GShard-style behaviour).
``capacity_factor <= 0`` disables dropping (capacity = T_local * top_k).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import current_mesh, current_rules, shard
from repro.models.common import ArchConfig, dense_init_a
from repro.models.layers import _act


def init_moe(kg, cfg: ArchConfig, abstract=False):
    d, f, e = cfg.d_model, cfg.moe_ff, cfg.n_experts
    pd = cfg.pdt
    return {
        "router": dense_init_a(kg(), (d, e), pd, abstract=abstract),
        "w_gate": dense_init_a(kg(), (e, d, f), pd, fan_in=d, abstract=abstract),
        "w_up": dense_init_a(kg(), (e, d, f), pd, fan_in=d, abstract=abstract),
        "w_down": dense_init_a(kg(), (e, f, d), pd, fan_in=f, abstract=abstract),
    }


def axes_moe(cfg: ArchConfig):
    return {
        "router": ("embed", None),
        "w_gate": ("experts", "embed_p", "expert_mlp_p"),
        "w_up": ("experts", "embed_p", "expert_mlp_p"),
        "w_down": ("experts", "expert_mlp_p", "embed_p"),
    }


def _route(x, router_w, top_k: int):
    """x [T,d] → (gates [T,k] fp32, ids [T,k] int32). Gates renormalized."""
    logits = (x @ router_w.astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.clip(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates, ids.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Oracle path
# ---------------------------------------------------------------------------

def moe_block_dense(params, cfg: ArchConfig, x):
    """Reference MoE: [B,T,d] → [B,T,d] computing every expert densely."""
    B, T, d = x.shape
    xf = x.reshape(B * T, d)
    gates, ids = _route(xf, params["router"], cfg.top_k)
    act = _act(cfg.act)
    cd = cfg.cdt
    h = jnp.einsum("td,edf->tef", xf, params["w_gate"].astype(cd))
    u = jnp.einsum("td,edf->tef", xf, params["w_up"].astype(cd))
    o = jnp.einsum("tef,efd->ted", act(h) * u, params["w_down"].astype(cd))
    onehot = jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32)   # [T,k,E]
    combine = jnp.einsum("tke,tk->te", onehot, gates)                # [T,E]
    out = jnp.einsum("ted,te->td", o.astype(jnp.float32), combine)
    return out.reshape(B, T, d).astype(x.dtype)


# ---------------------------------------------------------------------------
# Production path
# ---------------------------------------------------------------------------

def _moe_local(x, router_w, w_gate, w_up, w_down, *, cfg: ArchConfig,
               n_model: int, model_axis: str | None):
    """Per-shard body.  x [T_loc, d]; expert weights are the local slice."""
    T_loc, d = x.shape
    E_loc = w_gate.shape[0]
    k = cfg.top_k
    if model_axis is not None:
        mi = jax.lax.axis_index(model_axis)
    else:
        mi = 0
    lo = mi * E_loc

    gates, ids = _route(x, router_w, k)
    flat_ids = ids.reshape(-1)                                  # [T*k]
    flat_gates = gates.reshape(-1)
    tok_of = jnp.arange(T_loc * k, dtype=jnp.int32) // k

    if cfg.capacity_factor > 0:
        C = int(np.ceil(T_loc * k / max(n_model, 1) * cfg.capacity_factor))
        C = min(max(C, 1), T_loc * k)
    else:
        C = T_loc * k

    is_local = (flat_ids >= lo) & (flat_ids < lo + E_loc)
    sort_key = jnp.where(is_local, flat_ids, cfg.n_experts + 1)
    order = jnp.argsort(sort_key)[:C]
    sel_ids = flat_ids[order]
    sel_tok = tok_of[order]
    valid = is_local[order]

    rows = x[sel_tok] * valid[:, None].astype(x.dtype)
    gsz = jnp.sum(sel_ids[:, None] == (lo + jnp.arange(E_loc))[None, :],
                  axis=0).astype(jnp.int32)
    # overflow of the last group beyond C is implicitly dropped by argsort cut;
    # clamp group sizes so they sum to <= C.
    gsz = jnp.minimum(gsz, C - jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                                jnp.cumsum(gsz)[:-1]]))
    gsz = jnp.maximum(gsz, 0)

    cd = cfg.cdt
    act = _act(cfg.act)
    # NOTE: no preferred_element_type=f32 here — XLA hoists the implied
    # f32 conversion of the *stacked* expert weights out of the layer scan
    # (≈100 GiB of loop-invariant converts for Jamba/Kimi).  On TPU the MXU
    # accumulates bf16×bf16 in f32 natively; the output is cast below.
    h = jax.lax.ragged_dot(rows, w_gate.astype(cd), gsz)
    u = jax.lax.ragged_dot(rows, w_up.astype(cd), gsz)
    o = jax.lax.ragged_dot((act(h) * u).astype(cd), w_down.astype(cd), gsz)
    o = o.astype(jnp.float32) * (flat_gates[order] * valid)[:, None]
    y = jnp.zeros((T_loc, d), jnp.float32).at[sel_tok].add(o)
    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)
    return y.astype(x.dtype)


def moe_block_sharded(params, cfg: ArchConfig, x, *, model_axis="model"):
    """Production MoE: [B,T,d] → [B,T,d] under shard_map on the current mesh
    (tokens sharded over all non-model axes, experts over the model axis).

    Falls back to the single-shard sort-based path when no mesh is installed.
    """
    mesh = current_mesh()
    B, T, d = x.shape
    xf = x.reshape(B * T, d)
    if mesh is None or model_axis not in mesh.axis_names:
        out = _moe_local(xf, params["router"], params["w_gate"],
                         params["w_up"], params["w_down"], cfg=cfg,
                         n_model=1, model_axis=None)
        return out.reshape(B, T, d)

    n_model = mesh.shape[model_axis]
    da = tuple(a for a in mesh.axis_names if a != model_axis)
    da_key = da if len(da) != 1 else da[0]
    body = functools.partial(_moe_local, cfg=cfg, n_model=n_model,
                             model_axis=model_axis)
    from repro.distributed.collectives import shard_map_compat
    fn = shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(P(da_key, None), P(None, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None)),
        out_specs=P(da_key, None),
    )
    out = fn(xf, params["router"], params["w_gate"], params["w_up"],
             params["w_down"])
    return out.reshape(B, T, d)


def _moe_local_2d(x, router_w, w_gate, w_up, w_down, *, cfg: ArchConfig,
                  model_axis: str, data_axes: tuple):
    """2D expert-parallel body for serving: experts sharded over the model
    axis AND the expert-FFN dim sharded over the data axes (weights never
    gathered).  x is replicated (decode token counts are tiny); each device
    computes its (E_local × f_local) slice — gate/up produce [C, f_local],
    the down matmul yields an f-partial [C, d] summed with psum over data,
    and the per-expert scatter combines with psum over model."""
    T, d = x.shape
    E_loc = w_gate.shape[0]
    k = cfg.top_k
    n_model = jax.lax.axis_size(model_axis)
    mi = jax.lax.axis_index(model_axis)
    lo = mi * E_loc

    gates, ids = _route(x, router_w, k)
    flat_ids = ids.reshape(-1)
    flat_gates = gates.reshape(-1)
    tok_of = jnp.arange(T * k, dtype=jnp.int32) // k

    if cfg.capacity_factor > 0:
        C = int(np.ceil(T * k / max(n_model, 1) * cfg.capacity_factor))
        C = min(max(C, 1), T * k)
    else:
        C = T * k

    is_local = (flat_ids >= lo) & (flat_ids < lo + E_loc)
    order = jnp.argsort(jnp.where(is_local, flat_ids,
                                  cfg.n_experts + 1))[:C]
    sel_ids = flat_ids[order]
    sel_tok = tok_of[order]
    valid = is_local[order]
    rows = x[sel_tok] * valid[:, None].astype(x.dtype)
    gsz = jnp.sum(sel_ids[:, None] == (lo + jnp.arange(E_loc))[None, :],
                  axis=0).astype(jnp.int32)
    gsz = jnp.minimum(gsz, C - jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(gsz)[:-1]]))
    gsz = jnp.maximum(gsz, 0)

    cd = cfg.cdt
    act = _act(cfg.act)
    h = jax.lax.ragged_dot(rows, w_gate.astype(cd), gsz)   # [C, f_local]
    u = jax.lax.ragged_dot(rows, w_up.astype(cd), gsz)
    o = jax.lax.ragged_dot((act(h) * u).astype(cd), w_down.astype(cd), gsz)
    o = o.astype(jnp.float32) * (flat_gates[order] * valid)[:, None]
    y = jnp.zeros((T, d), jnp.float32).at[sel_tok].add(o)
    # sum f-partials over data AND per-expert partials over model
    y = jax.lax.psum(y, data_axes + (model_axis,))
    return y.astype(x.dtype)


def moe_block_2d(params, cfg: ArchConfig, x, *, model_axis="model"):
    """Serving MoE with 2D-sharded expert weights (see serving_rules)."""
    mesh = current_mesh()
    B, T, d = x.shape
    xf = x.reshape(B * T, d)
    if mesh is None or model_axis not in mesh.axis_names:
        out = _moe_local(xf, params["router"], params["w_gate"],
                         params["w_up"], params["w_down"], cfg=cfg,
                         n_model=1, model_axis=None)
        return out.reshape(B, T, d)
    da = tuple(a for a in mesh.axis_names if a != model_axis)
    da_key = da if len(da) != 1 else da[0]
    body = functools.partial(_moe_local_2d, cfg=cfg, model_axis=model_axis,
                             data_axes=da)
    from repro.distributed.collectives import shard_map_compat
    fn = shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(P(None, None), P(None, None),
                  P(model_axis, None, da_key), P(model_axis, None, da_key),
                  P(model_axis, da_key, None)),
        out_specs=P(None, None),
    )
    out = fn(xf, params["router"], params["w_gate"], params["w_up"],
             params["w_down"])
    return out.reshape(B, T, d)


def moe_block(params, cfg: ArchConfig, x, *, force_dense: bool = False):
    if force_dense or (cfg.n_experts <= 8 and current_mesh() is None):
        return moe_block_dense(params, cfg, x)
    rules = current_rules()
    if rules is not None and rules.table.get("moe_mode") == "2d":
        return moe_block_2d(params, cfg, x)
    return moe_block_sharded(params, cfg, x)
