"""Model registry: build a model object from an ArchConfig."""

from __future__ import annotations

from repro.models.common import ArchConfig
from repro.models.encdec import EncDecLM
from repro.models.transformer import TransformerLM


def build_model(cfg: ArchConfig):
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    return TransformerLM(cfg)
