from repro.models.common import ArchConfig
from repro.models.registry import build_model

__all__ = ["ArchConfig", "build_model"]
