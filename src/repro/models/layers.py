"""Core neural-net layers: RMSNorm, RoPE / M-RoPE, GQA attention, gated MLP.

All functions are pure; parameters are nested dicts created by the matching
``init_*`` helpers.  Every init helper has a twin ``axes_*`` returning the
pytree of logical-axis tuples used for sharding.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard
from repro.models.common import ArchConfig, dense_init_a, ones_a

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def init_norm(kg, cfg: ArchConfig, abstract=False):
    return {"scale": ones_a(kg(), (cfg.d_model,), cfg.pdt, abstract=abstract)}


def axes_norm(cfg: ArchConfig):
    return {"scale": ("embed",)}


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE and Qwen2-VL style M-RoPE)
# ---------------------------------------------------------------------------

def _rope_angles(positions, dim: int, theta: float):
    """positions [..., T] → cos/sin [..., T, dim//2] (float32)."""
    inv = 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float32) / dim))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float):
    """x [B, T, H, D], positions [B, T] → rotated x (interleaved halves)."""
    d = x.shape[-1]
    cos, sin = _rope_angles(positions, d, theta)          # [B, T, d/2]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections: Sequence[int]):
    """Qwen2-VL multimodal RoPE.

    x [B, T, H, D]; positions3 [B, 3, T] (temporal, height, width ids);
    ``sections`` gives per-component rotary dims summing to D//2.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    cos_parts, sin_parts = [], []
    lo = 0
    inv_full = 1.0 / (theta ** (np.arange(0, d, 2, dtype=np.float32) / d))
    for comp, sec in enumerate(sections):
        pos = positions3[:, comp, :]                       # [B, T]
        ang = pos[..., None].astype(jnp.float32) * inv_full[lo:lo + sec]
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        lo += sec
    cos = jnp.concatenate(cos_parts, axis=-1)[:, :, None, :]
    sin = jnp.concatenate(sin_parts, axis=-1)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def rope_for(cfg: ArchConfig, x, positions):
    """Dispatch RoPE vs M-RoPE.  positions is [B,T] or [B,3,T] for vlm."""
    if cfg.mrope_sections:
        if positions.ndim == 2:                            # text-only: t=h=w
            positions = jnp.broadcast_to(positions[:, None, :],
                                         (positions.shape[0], 3, positions.shape[1]))
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)


# ---------------------------------------------------------------------------
# Attention masks
# ---------------------------------------------------------------------------

def causal_mask(q_pos, k_pos):
    """bool[..., Tq, Tk]: k may be attended iff k_pos <= q_pos."""
    return k_pos[..., None, :] <= q_pos[..., :, None]


def block_causal_mask(q_pos, k_pos, block_size: int):
    """Block-diffusion mask: bidirectional within a block, causal across.

    Allowed iff block(k) <= block(q).
    """
    qb = q_pos // block_size
    kb = k_pos // block_size
    return kb[..., None, :] <= qb[..., :, None]


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(kg, cfg: ArchConfig, abstract=False):
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    pd = cfg.pdt
    return {
        "wq": dense_init_a(kg(), (d, h * hd), pd, abstract=abstract),
        "wk": dense_init_a(kg(), (d, kvh * hd), pd, abstract=abstract),
        "wv": dense_init_a(kg(), (d, kvh * hd), pd, abstract=abstract),
        "wo": dense_init_a(kg(), (h * hd, d), pd, fan_in=h * hd, abstract=abstract),
    }


def axes_attention(cfg: ArchConfig):
    return {
        "wq": ("embed_p", "heads_p"),
        "wk": ("embed_p", "heads_p"),
        "wv": ("embed_p", "heads_p"),
        "wo": ("heads_p", "embed_p"),
    }


def qkv_project(params, cfg: ArchConfig, x, positions):
    """x [B,T,d] → q [B,T,H,D], k/v [B,T,KVH,D], RoPE applied to q and k."""
    B, T, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    cd = cfg.cdt
    q = (x @ params["wq"].astype(cd)).reshape(B, T, h, hd)
    k = (x @ params["wk"].astype(cd)).reshape(B, T, kvh, hd)
    v = (x @ params["wv"].astype(cd)).reshape(B, T, kvh, hd)
    rp = positions if positions.ndim > 2 else positions
    q = rope_for(cfg, q, rp)
    k = rope_for(cfg, k, rp)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def sdpa(q, k, v, mask, *, scale: float | None = None):
    """Grouped-query scaled dot-product attention (pure-XLA path).

    q [B,T,H,D], k/v [B,S,KVH,D], mask bool[B,1,T,S] or [B,H or KVH...]-
    broadcastable.  Softmax in fp32.
    """
    B, T, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qg = q.reshape(B, T, KVH, G, D)
    logits = jnp.einsum("btkgd,bskd->bkgts", qg, k,
                        preferred_element_type=jnp.float32) * scale
    # mask [B,1,T,S] → [B,1,1,T,S]
    logits = jnp.where(mask[:, :, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", probs.astype(q.dtype), v)
    return out.reshape(B, T, H, D)


def sdpa_partial(q, k, v, mask, *, scale: float | None = None):
    """Unnormalized flash-style partial attention.

    Returns ``(acc, m, l)`` with ``acc = Σ_j e^{logit_j - m} v_j``,
    ``m = max_j logit_j`` and ``l = Σ_j e^{logit_j - m}`` so that partials over
    disjoint KV sets combine exactly (used for cache+window fusion and for
    sequence-sharded split-KV decode, where the reductions over the KV axis
    become XLA all-reduces).  Shapes: q [B,T,H,D], k/v [B,S,KVH,D],
    mask bool[B,1,T,S]; acc [B,T,H,D], m/l [B,T,H] (fp32).
    """
    B, T, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qg = q.reshape(B, T, KVH, G, D)
    logits = jnp.einsum("btkgd,bskd->bkgts", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask[:, :, None, :, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1)                              # [B,KVH,G,T]
    e = jnp.exp(logits - m[..., None])
    e = jnp.where(mask[:, :, None, :, :], e, 0.0)
    l = jnp.sum(e, axis=-1)
    acc = jnp.einsum("bkgts,bskd->btkgd", e.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    acc = acc.reshape(B, T, H, D)
    m = jnp.transpose(m, (0, 3, 1, 2)).reshape(B, T, H)
    l = jnp.transpose(l, (0, 3, 1, 2)).reshape(B, T, H)
    return acc, m, l


def _kind_mask(kind: str, qp, kp, block_size: int):
    """qp [B,tq], kp [B,tk] → bool [B,tq,tk]."""
    if kind == "all":
        return jnp.ones((qp.shape[0], qp.shape[1], kp.shape[1]), bool)
    if kind == "causal":
        return causal_mask(qp, kp)
    if kind == "block_causal":
        return block_causal_mask(qp, kp, block_size)
    raise ValueError(kind)


def flash_partial(q, k, v, *, q_pos, k_pos, k_valid, kind="causal",
                  block_size: int = 0, q_chunk: int = 512,
                  kv_chunk: int = 1024, scale: float | None = None):
    """Memory-efficient (Rabe–Staats / flash-style) partial attention in XLA.

    Scans query chunks × KV chunks with an online softmax so peak memory is
    O(q_chunk · kv_chunk) instead of O(T·S).  The mask is built on the fly
    from positions (never materialized at [T,S]).  Used by the serving paths
    (32k prefill, decode-over-cache); returns flash partials (acc, m, l) so
    the caller can combine with other KV segments (window self-attention,
    sequence-sharded splits).

    q [B,T,H,D]; k/v [B,S,KVH,D]; q_pos [B,T]; k_pos [B,S]; k_valid [B,S].
    Returns acc [B,T,H,D] fp32, m/l [B,T,H] fp32.
    """
    B, T, H, D = q.shape
    S = k.shape[1]
    KVH = k.shape[2]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qc = min(q_chunk, T)
    kc = min(kv_chunk, S)

    Tp = -(-T // qc) * qc
    Sp = -(-S // kc) * kc
    q = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    q_pos = jnp.pad(q_pos, ((0, 0), (0, Tp - T)))
    k = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    k_pos = jnp.pad(k_pos, ((0, 0), (0, Sp - S)))
    k_valid = jnp.pad(k_valid, ((0, 0), (0, Sp - S)))

    nq, nk = Tp // qc, Sp // kc
    # [nq, B, qc, ...] query chunks as scan xs
    qs = jnp.moveaxis(q.reshape(B, nq, qc, KVH, G, D), 1, 0)
    qps = jnp.moveaxis(q_pos.reshape(B, nq, qc), 1, 0)
    ks = jnp.moveaxis(k.reshape(B, nk, kc, KVH, D), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nk, kc, KVH, D), 1, 0)
    kps = jnp.moveaxis(k_pos.reshape(B, nk, kc), 1, 0)
    kvs = jnp.moveaxis(k_valid.reshape(B, nk, kc), 1, 0)

    def q_step(_, q_inp):
        qi, qpi = q_inp                                   # [B,qc,KVH,G,D]

        @jax.checkpoint
        def kv_step(carry, kv_inp):
            acc, m, l = carry
            ki, vi, kpi, kvi = kv_inp                     # [B,kc,KVH,D]
            logits = jnp.einsum("btkgd,bskd->bkgts", qi, ki,
                                preferred_element_type=jnp.float32) * scale
            msk = _kind_mask(kind, qpi, kpi, block_size) & kvi[:, None, :]
            msk = msk[:, None, None, :, :]                # [B,1,1,qc,kc]
            logits = jnp.where(msk, logits, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            corr = jnp.exp(m - m_new)
            e = jnp.exp(logits - m_new[..., None])
            e = jnp.where(msk, e, 0.0)
            l = l * corr + jnp.sum(e, axis=-1)
            pv = jnp.einsum("bkgts,bskd->bkgtd", e.astype(vi.dtype), vi,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, KVH, G, qc, D), jnp.float32)
        m0 = jnp.full((B, KVH, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, qc), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                      (ks, vs, kps, kvs))
        return None, (acc, m, l)

    _, (accs, ms, ls) = jax.lax.scan(q_step, None, (qs, qps))
    # accs [nq, B, KVH, G, qc, D] → [B, T, H, D]
    acc = jnp.moveaxis(accs, 0, 1).transpose(0, 1, 4, 2, 3, 5) \
        .reshape(B, Tp, H, D)[:, :T]
    m = jnp.moveaxis(ms, 0, 1).transpose(0, 1, 4, 2, 3).reshape(B, Tp, H)[:, :T]
    l = jnp.moveaxis(ls, 0, 1).transpose(0, 1, 4, 2, 3).reshape(B, Tp, H)[:, :T]
    return acc, m, l


def flash_partial_aligned(q, k, v, *, lengths, kind="causal",
                          block_size: int = 0, chunk: int = 512,
                          scale: float | None = None):
    """Triangular flash attention for position-aligned full sequences.

    For causal / block-causal masks over contiguous positions 0..T-1, any kv
    chunk strictly above the diagonal is fully masked.  Instead of scanning
    the full nq×nk rectangle and masking (≈2× wasted MXU work + traffic),
    scan only the nq(nq+1)/2 lower-triangular (q-chunk, kv-chunk) pairs —
    the pair list is static, so the savings are structural (visible in HLO
    FLOPs, real on TPU).  Requires chunk % block_size == 0 so diffusion
    blocks never straddle a chunk boundary.

    Returns flash partials (acc fp32 [B,T,H,D], m, l [B,T,H]).
    """
    B, T, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    chunk = min(chunk, T)
    if block_size:
        chunk = max(chunk - chunk % block_size, block_size)
    if T % chunk != 0:
        # fall back to the generic path for ragged lengths
        pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        return flash_partial(q, k, v, q_pos=pos, k_pos=pos,
                             k_valid=jnp.arange(T)[None] < lengths[:, None],
                             kind=kind, block_size=block_size, scale=scale)
    nq = T // chunk
    pairs = [(qi, ki) for qi in range(nq) for ki in range(qi + 1)]
    qi_arr = jnp.array([p[0] for p in pairs], jnp.int32)
    ki_arr = jnp.array([p[1] for p in pairs], jnp.int32)
    first = jnp.array([p[1] == 0 for p in pairs])
    last = jnp.array([p[1] == p[0] for p in pairs])

    qs = jnp.moveaxis(q.reshape(B, nq, chunk, KVH, G, D), 1, 0)
    ks = jnp.moveaxis(k.reshape(B, nq, chunk, KVH, D), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nq, chunk, KVH, D), 1, 0)

    acc0 = jnp.zeros((B, KVH, G, chunk, D), jnp.float32)
    m0 = jnp.full((B, KVH, G, chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KVH, G, chunk), jnp.float32)
    out_acc0 = jnp.zeros((nq,) + acc0.shape, jnp.float32)
    out_m0 = jnp.full((nq,) + m0.shape, NEG_INF, jnp.float32)
    out_l0 = jnp.zeros((nq,) + l0.shape, jnp.float32)

    @jax.checkpoint
    def step(carry, inp):
        acc, m, l, out_acc, out_m, out_l = carry
        qi, ki, fst, lst = inp
        qb = jax.lax.dynamic_index_in_dim(qs, qi, 0, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(ks, ki, 0, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vs, ki, 0, keepdims=False)
        acc = jnp.where(fst, 0.0, acc)
        m = jnp.where(fst, NEG_INF, m)
        l = jnp.where(fst, 0.0, l)
        logits = jnp.einsum("btkgd,bskd->bkgts", qb, kb,
                            preferred_element_type=jnp.float32) * scale
        qpos = qi * chunk + jax.lax.broadcasted_iota(jnp.int32,
                                                     (chunk, chunk), 0)
        kpos = ki * chunk + jax.lax.broadcasted_iota(jnp.int32,
                                                     (chunk, chunk), 1)
        if kind == "block_causal":
            ok = kpos // block_size <= qpos // block_size
        else:
            ok = kpos <= qpos
        ok = ok[None] & (ki * chunk + jnp.arange(chunk)[None, None, :]
                         < lengths[:, None, None])
        okb = ok[:, None, None]
        logits = jnp.where(okb, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        corr = jnp.exp(m - m_new)
        e = jnp.exp(logits - m_new[..., None])
        e = jnp.where(okb, e, 0.0)
        l = l * corr + jnp.sum(e, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgts,bskd->bkgtd", e.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        m = m_new

        def put(buf, val):
            cur = jax.lax.dynamic_index_in_dim(buf, qi, 0, keepdims=False)
            return jax.lax.dynamic_update_index_in_dim(
                buf, jnp.where(lst, val, cur), qi, 0)

        out_acc = put(out_acc, acc)
        out_m = put(out_m, m)
        out_l = put(out_l, l)
        return (acc, m, l, out_acc, out_m, out_l), None

    (_, _, _, out_acc, out_m, out_l), _ = jax.lax.scan(
        step, (acc0, m0, l0, out_acc0, out_m0, out_l0),
        (qi_arr, ki_arr, first, last))
    # [nq, B, KVH, G, chunk, D] → [B, T, H, D]
    acc = jnp.moveaxis(out_acc, 0, 1).transpose(0, 1, 4, 2, 3, 5) \
        .reshape(B, T, H, D)
    m = jnp.moveaxis(out_m, 0, 1).transpose(0, 1, 4, 2, 3).reshape(B, T, H)
    l = jnp.moveaxis(out_l, 0, 1).transpose(0, 1, 4, 2, 3).reshape(B, T, H)
    return acc, m, l


def combine_partials(parts, out_dtype):
    """Combine flash partials [(acc, m, l), ...] into normalized output
    (shared implementation: :func:`repro.kernels.ops.combine_flash_partials`)."""
    from repro.kernels.ops import combine_flash_partials
    return combine_flash_partials(parts, out_dtype=out_dtype)


def attn_output(params, cfg: ArchConfig, out):
    B, T = out.shape[:2]
    y = out.reshape(B, T, -1) @ params["wo"].astype(cfg.cdt)
    return shard(y, "batch", "seq", "embed")


def attention_block(params, cfg: ArchConfig, x, positions, mask):
    q, k, v = qkv_project(params, cfg, x, positions)
    out = sdpa(q, k, v, mask)
    return attn_output(params, cfg, out), (k, v)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def init_mlp(kg, cfg: ArchConfig, abstract=False, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    pd = cfg.pdt
    out = {
        "w_up": dense_init_a(kg(), (d, f), pd, abstract=abstract),
        "w_down": dense_init_a(kg(), (f, d), pd, fan_in=f, abstract=abstract),
    }
    if cfg.gated_mlp:
        out["w_gate"] = dense_init_a(kg(), (d, f), pd, abstract=abstract)
    return out


def axes_mlp(cfg: ArchConfig):
    out = {"w_up": ("embed_p", "mlp_p"),
           "w_down": ("mlp_p", "embed_p")}
    if cfg.gated_mlp:
        out["w_gate"] = ("embed_p", "mlp_p")
    return out


def mlp_block(params, cfg: ArchConfig, x):
    cd = cfg.cdt
    u = x @ params["w_up"].astype(cd)
    if cfg.gated_mlp:
        g = _act(cfg.act)(x @ params["w_gate"].astype(cd))
        h = g * u
    else:
        h = _act(cfg.act)(u)
    h = shard(h, "batch", "seq", "mlp")
    return shard(h @ params["w_down"].astype(cd), "batch", "seq", "embed")
