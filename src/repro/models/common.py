"""Shared model configuration and parameter-initialization helpers.

One :class:`ArchConfig` dataclass covers every assigned architecture family
(dense / moe / hybrid / ssm / encdec / vlm).  Parameters are plain nested
dicts of jnp arrays; layer stacks are stored stacked along a leading ``L``
axis so the forward pass is a single ``lax.scan``, which keeps HLO size (and
therefore 512-device compile time) independent of depth.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ArchConfig:
    # identity ----------------------------------------------------------
    name: str = "model"
    family: str = "dense"          # dense | moe | hybrid | ssm | encdec | vlm
    # trunk -------------------------------------------------------------
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 256
    vocab_size: int = 512
    head_dim: int = 0              # 0 → d_model // n_heads
    act: str = "silu"              # silu (SwiGLU) | gelu (GeGLU)
    gated_mlp: bool = True         # False → plain up/act/down FFN
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    max_seq_len: int = 8192
    # MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0              # 0 → d_ff
    capacity_factor: float = 2.0
    # hybrid (jamba-style) -----------------------------------------------
    attn_period: int = 0           # 0 → every layer is attention
    attn_offset: int = 3           # index of the attn layer inside a period
    moe_every: int = 0             # 0 → dense FFN everywhere; k → MoE on idx%k==k-1
    d_state: int = 16
    d_conv: int = 4
    mamba_expand: int = 2
    # rwkv ----------------------------------------------------------------
    rwkv_head_dim: int = 64
    rwkv_lora_rank: int = 32
    # encoder-decoder -----------------------------------------------------
    n_enc_layers: int = 0
    # vlm (M-RoPE) --------------------------------------------------------
    mrope_sections: tuple = ()     # per-section rotary dims, sums to head_dim//2
    # diffusion decoding --------------------------------------------------
    diffusion: bool = True         # block-diffusion decoding supported
    block_size: int = 32
    mask_token_id: int = 3         # reserved mask-token id
    confidence_threshold: float = 0.9
    # serving KV-cache dispatch -------------------------------------------
    paged_kv: bool = False         # serve through the paged KV pool
    kv_page_size: int = 16         # tokens per KV page
    paged_attn_impl: str = "kernel"  # kernel (Pallas; interpret off-TPU) | ref
    # dtypes --------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # sharding-rule overrides: ((logical_axis, mesh_axis_or_None), ...)
    rule_overrides: tuple = ()
    # scan/remat -----------------------------------------------------------
    remat: bool = False
    scan_layers: bool = True

    # derived -------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def moe_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def pdt(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdt(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def rwkv_n_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def is_attn_layer(self, idx: int) -> bool:
        if self.attn_period == 0:
            return True
        return idx % self.attn_period == self.attn_offset

    def is_moe_layer(self, idx: int) -> bool:
        if self.n_experts == 0:
            return False
        if self.moe_every == 0:
            return True
        return idx % self.moe_every == self.moe_every - 1

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (matches init exactly)."""
        from repro.models import registry  # local import to avoid cycles

        params = registry.build_model(self).init(jax.random.PRNGKey(0),
                                                 abstract=True)
        return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Initialization helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in: int | None = None):
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


class KeyGen:
    """Splittable RNG stream."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


def abstract_like(init_fn):
    """Wrap an init fn so it can produce ShapeDtypeStructs instead of arrays."""

    def wrapped(key, shape, dtype, *a, abstract=False, **kw):
        if abstract:
            return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))
        return init_fn(key, shape, dtype, *a, **kw)

    return wrapped


dense_init_a = abstract_like(dense_init)
embed_init_a = abstract_like(embed_init)


def zeros_a(key, shape, dtype, abstract=False):
    if abstract:
        return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))
    return jnp.zeros(shape, dtype)


def ones_a(key, shape, dtype, abstract=False):
    if abstract:
        return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))
    return jnp.ones(shape, dtype)
